"""POD (Eqs. 5-6) and Projection Planner (Eqs. 1-2) invariants —
unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import planner as PL
from repro.core import pod
from repro.core.calibrate import activation_norms, calibrate
from repro.core.rank_controller import run_ranking_controller
from repro.core.registry import projections
from repro.models import transformer as T
from tests.conftest import small_config


def test_outlier_ratio_known_case():
    # 99 ones and 1 thousand: mean ~10.99; alpha=5 -> only the big one
    m = jnp.concatenate([jnp.ones(99), jnp.array([1000.0])]).reshape(10, 10)
    r = float(pod.outlier_ratio(m, alpha=5.0))
    assert r == pytest.approx(1.0)


def test_weight_metric_matches_eq5():
    from repro.core.registry import Projection
    w = jnp.array([[1.0, -2.0], [3.0, -4.0]])
    anorm = jnp.array([2.0, 0.5])
    proj = Projection(0, "up", ("x",), "mlp_in", (0,))
    m = pod.weight_metric(w, anorm, proj)
    np.testing.assert_allclose(m, [[2.0, 4.0], [1.5, 2.0]])


def test_global_rank_normalised_mean_one():
    cfg = small_config(moe=True, mamba=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batches = [jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0,
                                  cfg.vocab) for i in range(2)]
    art = run_ranking_controller(params, cfg, batches)
    vals = [np.mean(v) for v in art.rank.values()]
    assert np.mean(vals) == pytest.approx(1.0, rel=1e-6)
    assert set(art.rank) == {p.key for p in projections(cfg)}


@given(st.lists(st.floats(0.0, 10.0), min_size=3, max_size=40),
       st.floats(0.05, 0.9))
@settings(max_examples=50, deadline=None)
def test_planner_mean_and_bounds(ranks, p):
    rank = {(i, "up"): r for i, r in enumerate(ranks)}
    rank = pod.normalize_rank(rank)
    targets = PL.plan_targets(rank, p)
    vals = np.array(list(targets.values()))
    assert abs(vals.mean() - p) < 1e-6          # Eq. 1/2 hold exactly
    assert (vals >= 0).all() and (vals <= PL.MAX_TARGET).all()


@given(st.floats(0.05, 0.9))
@settings(max_examples=20, deadline=None)
def test_planner_monotone(p):
    rank = pod.normalize_rank({(0, "a"): 1.0, (1, "a"): 2.0, (2, "a"): 4.0})
    t = PL.plan_targets(rank, p)
    # more outliers (higher rank) => pruned less
    assert t[(0, "a")] >= t[(1, "a")] >= t[(2, "a")]


def test_planner_weighted_mean():
    rank = pod.normalize_rank({(0, "a"): 1.0, (1, "a"): 3.0})
    weights = {(0, "a"): 100.0, (1, "a"): 300.0}
    t = PL.plan_targets(rank, 0.5, weights=weights)
    wmean = (t[(0, "a")] * 100 + t[(1, "a")] * 300) / 400
    assert wmean == pytest.approx(0.5, abs=1e-9)


def test_granularities():
    rank = pod.normalize_rank({(0, "q"): 1.0, (0, "up"): 2.0,
                               (1, "q"): 3.0, (1, "up"): 4.0})
    g = PL.plan(rank, 0.4, "global")
    assert set(g.values()) == {0.4}
    l = PL.plan(rank, 0.4, "layer")
    assert l[(0, "q")] == l[(0, "up")]       # per-layer uniform
    assert l[(0, "q")] != l[(1, "q")]
    pr = PL.plan(rank, 0.4, "projection")
    assert len(set(pr.values())) == 4


def test_calibration_accumulates_ssq():
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    b = [jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)]
    stats, n = calibrate(params, cfg, b)
    assert n == 16
    anorms = activation_norms(stats)
    a = anorms[(0, "attn_qkv")]
    assert a.shape == (cfg.d_model,)
    assert bool(jnp.all(a >= 0))
    # two identical batches double the sumsq -> sqrt(2) scaling
    stats2, _ = calibrate(params, cfg, b + b)
    np.testing.assert_allclose(activation_norms(stats2)[(0, "attn_qkv")],
                               a * np.sqrt(2), rtol=1e-6)
