"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_sparse.ops import (block_mask_from_weight_mask,
                                            blocksparse_matmul, plan_blocks)
from repro.kernels.block_sparse.ref import block_sparse_matmul_ref
from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels import counters
from repro.kernels.grouped_block_sparse.ops import (
    RAGGED_BLOCK_ROWS, grouped_blocksparse_matmul,
    ragged_blocksparse_matmul, stack_expert_plans)
from repro.kernels.grouped_block_sparse.ref import (
    grouped_block_sparse_matmul_ref, ragged_block_sparse_matmul_ref)
from repro.kernels.paged_attention.ops import paged_attention_decode
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd_scan.ops import ssd_apply
from repro.kernels.wanda_metric.ops import outlier_ratio as kernel_outlier
from repro.kernels.wanda_metric.ref import outlier_ratio_ref
from repro.models.layers import _dense_attention, paged_gather
from repro.models.ssm import ssd_chunked

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mkn", [(256, 512, 384), (128, 256, 128),
                                 (384, 384, 256)])
def test_block_sparse_matmul(dtype, mkn):
    M, K, N = mkn
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (M, K)).astype(dtype)
    w = jax.random.normal(ks[1], (K, N)).astype(dtype)
    mask = np.array(jax.random.uniform(ks[2], (K, N)) > 0.7)
    mask[:128, :128] = False                       # force a zero block
    w = jnp.where(jnp.asarray(mask), w, 0).astype(dtype)
    bm = block_mask_from_weight_mask(mask, 128, 128)
    counts, idx = plan_blocks(bm)
    y = blocksparse_matmul(x, w, counts, idx, interpret=True)
    yref = block_sparse_matmul_ref(x, w, jnp.asarray(bm), 128, 128)
    err = jnp.abs(y.astype(jnp.float32) - yref.astype(jnp.float32)).max()
    scale = jnp.abs(yref.astype(jnp.float32)).max() + 1e-9
    assert float(err / scale) < TOL[dtype]


def test_block_sparse_skips_zero_blocks():
    mask = np.zeros((256, 256), bool)
    mask[:128, :128] = True
    bm = block_mask_from_weight_mask(mask, 128, 128)
    counts, idx = plan_blocks(bm)
    assert counts.tolist() == [1, 0]               # column 1 fully skipped
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 256)) * jnp.asarray(mask)
    y = blocksparse_matmul(x, w, counts, idx, interpret=True)
    assert float(jnp.abs(y[:, 128:]).max()) == 0.0


def _expert_problem(E=4, M=96, K=64, N=80, block=16, keep=0.4, seed=0):
    """Random per-expert weights with diverging tile densities + the
    stacked grouped plan built from independent per-expert plans."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(E, M, K)).astype(np.float32))
    w = rng.normal(size=(E, K, N)).astype(np.float32)
    masks = np.zeros((E, K, N), bool)
    for e in range(E):
        # tile-level masks so pruned tiles are exactly skippable tiles;
        # density rises with e => per-expert max_nnz diverges
        bm = rng.random((K // block, N // block)) < keep + 0.15 * e
        bm[0, 0] = True                     # never a fully empty plan
        masks[e] = np.repeat(np.repeat(bm, block, 0), block, 1)
    w = np.where(masks, w, 0.0)
    counts_e, indices_e, bms = [], [], []
    for e in range(E):
        bm = block_mask_from_weight_mask(masks[e], block, block)
        c, i = plan_blocks(bm)
        counts_e.append(c)
        indices_e.append(i)
        bms.append(bm)
    counts, indices = stack_expert_plans(counts_e, indices_e)
    return (x, jnp.asarray(w), jnp.asarray(counts), jnp.asarray(indices),
            counts_e, indices_e, jnp.asarray(np.stack(bms)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_block_sparse_vs_ref(dtype):
    B = 16
    x, w, counts, indices, _, _, bms = _expert_problem()
    y = grouped_blocksparse_matmul(x.astype(dtype), w.astype(dtype),
                                   counts, indices, block_k=B, block_n=B,
                                   interpret=True)
    yref = grouped_block_sparse_matmul_ref(x.astype(dtype), w.astype(dtype),
                                           bms, B, B)
    err = jnp.abs(y.astype(jnp.float32) - yref.astype(jnp.float32)).max()
    scale = jnp.abs(yref.astype(jnp.float32)).max() + 1e-9
    assert float(err / scale) < TOL[dtype]


@pytest.mark.parametrize("block_m", [None, 16, 48])
def test_grouped_matches_per_expert_launches(block_m):
    """One grouped launch == E per-expert block_sparse launches,
    bitwise (same f32 accumulation order per expert), for both the
    resident-panel default and explicit M tiling."""
    B = 16
    x, w, counts, indices, counts_e, indices_e, _ = _expert_problem()
    y = grouped_blocksparse_matmul(x, w, counts, indices, block_m=block_m,
                                   block_k=B, block_n=B, interpret=True)
    for e in range(x.shape[0]):
        ye = blocksparse_matmul(x[e], w[e], counts[e], indices[e],
                                block_m=16, block_k=B, block_n=B,
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(y[e]), np.asarray(ye))
        # and vs each expert's own (unpadded-max_nnz) solo plan
        solo = blocksparse_matmul(x[e], w[e], counts_e[e], indices_e[e],
                                  block_m=16, block_k=B, block_n=B,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(y[e]), np.asarray(solo),
                                   rtol=0, atol=2e-5)


def test_grouped_skips_fully_pruned_expert_column():
    """count==0 block-columns produce exact zeros, per expert — a column
    dense in expert 1 can be fully skipped in expert 0."""
    B, E, K, N = 16, 2, 32, 32
    masks = np.zeros((E, K, N), bool)
    masks[0, :, :16] = True                 # expert 0: column 1 empty
    masks[1, :, :] = True                   # expert 1: fully dense
    w = np.where(masks, 1.0, 0.0).astype(np.float32)
    counts_e, indices_e = zip(*(plan_blocks(
        block_mask_from_weight_mask(masks[e], B, B)) for e in range(E)))
    counts, indices = stack_expert_plans(counts_e, indices_e)
    x = jnp.ones((E, 16, K), jnp.float32)
    y = grouped_blocksparse_matmul(x, jnp.asarray(w), jnp.asarray(counts),
                                   jnp.asarray(indices), block_k=B,
                                   block_n=B, interpret=True)
    assert float(jnp.abs(y[0, :, 16:]).max()) == 0.0
    assert float(jnp.abs(y[1]).min()) > 0.0


# ------------------------------------------- occupancy-aware dispatch

def _occupancy_rows(pattern, E, M, rng):
    """Per-expert live-row counts for an occupancy pattern."""
    if pattern == "all-empty":
        return np.zeros(E, np.int64)
    if pattern == "one-hot":
        rows = np.zeros(E, np.int64)
        rows[rng.integers(E)] = max(1, M // 3)
        return rows
    if pattern == "skewed":
        rows = np.zeros(E, np.int64)
        rows[0] = M
        for e in range(1, E):
            rows[e] = max(0, 3 - e)
        return rows
    if pattern == "full":
        return np.full(E, M, np.int64)
    return rng.integers(0, M + 1, E)          # randomized fuzz


OCCUPANCY_PATTERNS = ["all-empty", "one-hot", "skewed", "full",
                      "random-0", "random-1", "random-2"]


@pytest.mark.parametrize("block_m", [None, 16])
@pytest.mark.parametrize("pattern", OCCUPANCY_PATTERNS)
def test_grouped_masked_occupancy_fuzz(pattern, block_m):
    """The occupancy-masked grouped launch: live rows bitwise-match the
    unmasked launch, fully-dead experts produce exact zeros, and the
    counters pin that empty experts contribute no computed-expert work."""
    B = 16
    x, w, counts, indices, _, _, _ = _expert_problem()
    E, M, _ = x.shape
    rng = np.random.default_rng(abs(hash(pattern)) % 2**32)
    rows = _occupancy_rows(pattern, E, M, rng)
    row_live = jnp.asarray(np.arange(M)[None, :] < rows[:, None])
    counters.reset()
    y = grouped_blocksparse_matmul(x, w, counts, indices, block_m=block_m,
                                   block_k=B, block_n=B, interpret=True,
                                   row_live=row_live)
    snap = counters.snapshot()
    occ = int((rows > 0).sum())
    assert snap["grouped_block_sparse"] == 1
    assert snap.get("grouped_block_sparse_experts_computed", 0) == occ
    y_full = grouped_blocksparse_matmul(x, w, counts, indices,
                                        block_m=block_m, block_k=B,
                                        block_n=B, interpret=True)
    for e in range(E):
        np.testing.assert_array_equal(np.asarray(y[e, :rows[e]]),
                                      np.asarray(y_full[e, :rows[e]]))
    if (rows == 0).any():
        dead = np.asarray(y)[rows == 0]
        assert float(np.abs(dead).max()) == 0.0


@pytest.mark.parametrize("pattern", OCCUPANCY_PATTERNS)
def test_ragged_occupancy_fuzz(pattern):
    """The ragged kernel over packed per-expert segments: each occupied
    segment bitwise-matches that expert's own block_sparse launch, dead
    padding tiles are exact zeros, and the counters pin that experts
    with zero routed tokens launch zero tile work."""
    B = 16
    _, w, counts, indices, _, _, bms = _expert_problem()
    E, K, _ = w.shape
    A = RAGGED_BLOCK_ROWS
    rng = np.random.default_rng(abs(hash(pattern)) % 2**32)
    rows = _occupancy_rows(pattern, E, 48, rng)
    rows = np.minimum(rows, 48)
    seg = -(-rows // A) * A
    ends = np.cumsum(seg)
    off = ends - seg
    m_max = int(max(ends[-1], A)) + A          # leave >=1 dead tail tile
    tile_expert = np.full(m_max // A, -1, np.int32)
    for e in range(E):
        tile_expert[off[e] // A: ends[e] // A] = e
    x = np.zeros((m_max, K), np.float32)
    for e in range(E):
        x[off[e]:off[e] + rows[e]] = rng.normal(size=(rows[e], K))
    counters.reset()
    y = ragged_blocksparse_matmul(jnp.asarray(x), w, counts, indices,
                                  jnp.asarray(tile_expert), block_k=B,
                                  block_n=B, interpret=True)
    snap = counters.snapshot()
    occ = int((rows > 0).sum())
    assert snap["grouped_block_sparse_ragged"] == 1
    assert snap.get("grouped_block_sparse_ragged_experts_computed", 0) == occ
    # dead tiles: exact zeros
    dead = np.asarray(y).reshape(m_max // A, A, -1)[tile_expert < 0]
    assert dead.size and float(np.abs(dead).max()) == 0.0
    # vs the pure-jnp oracle
    yref = ragged_block_sparse_matmul_ref(jnp.asarray(x), w,
                                          tile_expert, A, bms, B, B)
    scale = float(jnp.abs(yref).max()) + 1e-9
    assert float(jnp.abs(y - yref).max() / scale) < TOL[jnp.float32]
    # each occupied segment == that expert's solo block_sparse launch,
    # bitwise (same tile height, same f32 accumulation order)
    for e in range(E):
        if rows[e] == 0:
            continue
        ye = blocksparse_matmul(jnp.asarray(x[off[e]:ends[e]]), w[e],
                                counts[e], indices[e], block_m=A,
                                block_k=B, block_n=B, interpret=True)
        np.testing.assert_array_equal(np.asarray(y[off[e]:ends[e]]),
                                      np.asarray(ye))


@pytest.mark.parametrize("shape", [(512, 768), (256, 256), (1024, 512)])
def test_wanda_outlier_kernel(shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    K, N = shape
    spikes = (jax.random.uniform(k2, shape) > 0.995).astype(jnp.float32)
    w = jax.random.normal(k1, shape) * (1 + 20 * spikes)
    a = jnp.abs(jax.random.normal(k2, (K,))) + 0.1
    r_k = float(kernel_outlier(w, a, alpha=5.0, interpret=True))
    r_r = float(outlier_ratio_ref(w, a, 5.0))
    assert r_k == pytest.approx(r_r, abs=1e-4)


@pytest.mark.parametrize("dims", [(2, 64, 3, 16, 8, 16),
                                  (1, 128, 2, 32, 16, 32),
                                  (2, 96, 1, 16, 8, 32)])
def test_ssd_scan_kernel(dims):
    B, L, H, P, N, chunk = dims
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xt = jax.random.normal(ks[0], (B, L, H, P))
    da = -jnp.abs(jax.random.normal(ks[1], (B, L, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    y_k = ssd_apply(xt, da, Bm, Cm, chunk=chunk, interpret=True)
    y_r, _ = ssd_chunked(xt, da, Bm, Cm, chunk)
    scale = float(jnp.abs(y_r).max()) + 1e-9
    assert float(jnp.abs(y_k - y_r).max() / scale) < 1e-5


@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(hq, hkv, dtype):
    B, S, D = 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, hkv, D)).astype(dtype)
    o_k = flash_attention_bshd(q, k, v, block_q=128, block_k=128,
                               interpret=True)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_r = _dense_attention(q, k, v, pos, pos, causal=True)
    err = jnp.abs(o_k.astype(jnp.float32) - o_r.astype(jnp.float32)).max()
    assert float(err) < (5e-6 if dtype == jnp.float32 else 3e-2)


# ------------------------------------------------------- paged attention

def _paged_case(hq, hkv, dtype, B=4, M=4, bs=8, D=16, seed=7):
    """Random paged decode problem: a shuffled arena (so physical order
    never matches logical order), ragged lengths, one query per row."""
    rng = np.random.default_rng(seed)
    nb = B * M
    k_arena = jnp.asarray(rng.normal(size=(nb + 1, bs, hkv, D)), dtype)
    v_arena = jnp.asarray(rng.normal(size=(nb + 1, bs, hkv, D)), dtype)
    tables = jnp.asarray(rng.permutation(nb).reshape(B, M), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, M * bs + 1, (B,)), jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, 1, hq, D)), dtype)
    return q, k_arena, v_arena, tables, lengths


@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel(hq, hkv, dtype):
    q, ka, va, tables, lengths = _paged_case(hq, hkv, dtype)
    o_k = paged_attention_decode(q, ka, va, tables, lengths,
                                 interpret=True)
    o_r = paged_attention_ref(q[:, 0].astype(jnp.float32),
                              ka.astype(jnp.float32),
                              va.astype(jnp.float32), tables, lengths)
    err = jnp.abs(o_k[:, 0].astype(jnp.float32) - o_r).max()
    assert float(err) < (5e-6 if dtype == jnp.float32 else 3e-2)


def test_paged_attention_matches_gather_path():
    """The kernel must agree with the serving gather path itself
    (paged_gather + _dense_attention with the decode-time length mask),
    not just the standalone oracle."""
    q, ka, va, tables, lengths = _paged_case(4, 2, jnp.float32, seed=11)
    o_k = paged_attention_decode(q, ka, va, tables, lengths,
                                 interpret=True)
    kview = paged_gather(ka, tables)
    vview = paged_gather(va, tables)
    T_kv = kview.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(T_kv, dtype=jnp.int32)[None, :],
                              (q.shape[0], T_kv))
    valid = kv_pos < lengths[:, None]
    # decode writes at position length-1, so causal == the length mask
    o_g = _dense_attention(q, kview, vview, (lengths - 1)[:, None],
                           kv_pos, causal=True, kv_valid=valid)
    assert float(jnp.abs(o_k - o_g).max()) < TOL[jnp.float32]


def test_paged_attention_scratch_masked_slot():
    """A slot mid-chunked-prefill rides the decode burst with its table
    masked to the scratch block and length clamped to 1 (its output is
    discarded): the kernel must stay finite for it and exact for the
    live rows."""
    q, ka, va, tables, lengths = _paged_case(4, 2, jnp.float32, seed=13)
    scratch = ka.shape[0] - 1
    tables = tables.at[1].set(scratch)
    lengths = lengths.at[1].set(1)
    o_k = paged_attention_decode(q, ka, va, tables, lengths,
                                 interpret=True)
    assert bool(jnp.all(jnp.isfinite(o_k)))
    o_r = paged_attention_ref(q[:, 0], ka, va, tables, lengths)
    live = np.array([0, 2, 3])
    err = jnp.abs(o_k[live, 0] - o_r[live]).max()
    assert float(err) < TOL[jnp.float32]


def test_paged_attention_shared_prefix_tables():
    """Prefix sharing maps the same physical blocks into several rows'
    tables: rows with identical tables, lengths, and queries must
    produce identical outputs, and both must match the oracle."""
    q, ka, va, tables, lengths = _paged_case(4, 2, jnp.float32, seed=17)
    tables = tables.at[2].set(tables[0])        # full shared view
    lengths = lengths.at[2].set(lengths[0])
    q = q.at[2].set(q[0])
    tables = tables.at[3, :2].set(tables[1, :2])  # shared 2-block prefix
    o_k = paged_attention_decode(q, ka, va, tables, lengths,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(o_k[2]), np.asarray(o_k[0]))
    o_r = paged_attention_ref(q[:, 0], ka, va, tables, lengths)
    assert float(jnp.abs(o_k[:, 0] - o_r).max()) < TOL[jnp.float32]
