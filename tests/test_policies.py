"""Scheduler admission policies: the SCHEDULERS registry, priority
ordering with deterministic bypass-counted aging (no starvation), EDF
deadline ordering with the prefill/decode interleave budget, and the
behavior-preservation pin — ``scheduler="fifo"`` reproduces the PR 6
strict-arrival admission order token-for-token even when requests carry
priorities and deadlines."""
import jax
import jax.numpy as jnp
import pytest

from conftest import small_config
from repro.models import transformer as T
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.policies import (SCHEDULERS, FifoPolicy, PriorityPolicy,
                                  SLOPolicy, make_policy)
from repro.serve.scheduler import Request, Scheduler


def req(uid, arrival=0.0, priority=0, deadline_ms=None, n=4):
    return Request(uid=uid, prompt=[1, 2, 3], max_new_tokens=n,
                   arrival=arrival, priority=priority,
                   deadline_ms=deadline_ms)


def drain(policy, now=0.0):
    order = []
    while policy.head(now) is not None:
        order.append(policy.pop().uid)
    return order


# ------------------------------------------------------------- registry

def test_registry_names_and_factory():
    assert {"fifo", "priority", "slo"} <= set(SCHEDULERS.names())
    assert isinstance(make_policy("fifo"), FifoPolicy)
    assert isinstance(make_policy("priority"), PriorityPolicy)
    assert isinstance(make_policy("slo"), SLOPolicy)
    with pytest.raises(KeyError):
        make_policy("nope")
    with pytest.raises(ValueError):
        ServeConfig(scheduler="nope")


# ----------------------------------------------------------------- fifo

def test_fifo_strict_arrival_order():
    pol = make_policy("fifo")
    for u in range(4):
        pol.push(req(u, priority=3 - u, deadline_ms=1.0))  # both ignored
    assert drain(pol) == [0, 1, 2, 3]


def test_fifo_holds_unarrived_head():
    pol = make_policy("fifo")
    pol.push(req(0, arrival=5.0))
    pol.push(req(1, arrival=0.0))
    # head is strictly q[0]: an unarrived head blocks, never reorders
    assert pol.head(0.0) is None
    assert pol.head(6.0).uid == 0
    assert pol.next_arrival() == 5.0


# ------------------------------------------------------------- priority

def test_priority_ordering_then_seq():
    pol = make_policy("priority")
    pol.push(req(0, priority=0))
    pol.push(req(1, priority=2))
    pol.push(req(2, priority=2))
    pol.push(req(3, priority=1))
    pol.head(0.0)
    assert pol.pop().uid == 1           # highest priority, earliest seq
    # uid 0 has been bypassed once (age 1 -> effective 1), tying uid 3;
    # and uid 2 (priority 2) still outranks both
    pol.head(0.0)
    assert pol.pop().uid == 2


def test_priority_aging_prevents_starvation():
    """A priority-0 request must not starve behind an endless stream of
    priority-5 arrivals: each bypass ages it by 1, so after 5 bypasses
    it ties (and then beats, by seq) fresh priority-5 requests."""
    pol = PriorityPolicy(aging=1.0)
    pol.push(req(0, priority=0))
    popped = []
    uid = 1
    for _ in range(12):
        pol.push(req(uid, priority=5))
        uid += 1
        pol.head(0.0)
        popped.append(pol.pop().uid)
    assert 0 in popped, "priority-0 request starved"
    # exactly 5 bypasses before it wins a tie on age
    assert popped.index(0) == 5


def test_priority_aging_zero_starves():
    pol = PriorityPolicy(aging=0.0)
    pol.push(req(0, priority=0))
    for uid in range(1, 9):
        pol.push(req(uid, priority=5))
        pol.head(0.0)
        assert pol.pop().uid == uid     # the low-priority one never runs


# ------------------------------------------------------------------ slo

def test_slo_edf_ordering():
    pol = make_policy("slo")
    pol.push(req(0))                                # no deadline = +inf
    pol.push(req(1, deadline_ms=500.0))
    pol.push(req(2, deadline_ms=100.0))
    pol.push(req(3, arrival=0.2, deadline_ms=100.0))  # absolute 0.3s
    assert drain(pol, now=1.0) == [2, 3, 1, 0]


def test_slo_deadline_is_absolute():
    pol = make_policy("slo")
    pol.push(req(0, arrival=0.0, deadline_ms=1000.0))   # due at 1.0s
    pol.push(req(1, arrival=0.9, deadline_ms=50.0))     # due at 0.95s
    assert drain(pol, now=1.0) == [1, 0]


def test_slo_prefill_budget():
    pol = SLOPolicy(prefill_budget=1)
    assert pol.prefill_budget(0) is None        # nothing decoding: flood
    assert pol.prefill_budget(3) == 1           # decoding: cap chunks
    assert make_policy("fifo").prefill_budget(3) is None


# ------------------------------------------------- scheduler integration

def test_scheduler_priority_admission_order():
    s = Scheduler(max_slots=1, max_seq=16, policy="priority")
    for u, p in ((0, 0), (1, 5), (2, 1)):
        s.submit(req(u, priority=p))
    order = []
    while s.head(0.0) is not None:
        slot = s.admissions(0.0)[0]
        order.append(slot.request.uid)
        s.started(slot, first_token=7, now=0.0)     # budget 4: stays
        del s.slots[slot.index]                     # hand the slot back
        s.free.append(slot.index)
    # uid 1 (priority 5) first; popping it ages bypassed uid 0 to
    # effective 1, tying uid 2 (priority 1) — earlier submission wins
    assert order == [1, 0, 2]


def test_scheduler_backpressure_holds_policy_head():
    s = Scheduler(max_slots=2, max_seq=16, policy="slo")
    s.submit(req(0, deadline_ms=10.0))
    s.submit(req(1))
    # resource gate refuses the EDF head -> admission stalls entirely
    # rather than reordering around it
    assert s.admissions(0.0, can_admit=lambda r: r.uid != 0) == []
    assert len(s.queue) == 2


# --------------------------------------------- slo budget engine wiring

def _spy_budget(monkeypatch):
    """Record every ``n_decoding`` the engine hands the slo policy."""
    calls = []
    orig = SLOPolicy.prefill_budget

    def spy(self, n_decoding):
        calls.append(n_decoding)
        return orig(self, n_decoding)

    monkeypatch.setattr(SLOPolicy, "prefill_budget", spy)
    return calls


def test_slo_budget_unlimited_while_only_prefilling(monkeypatch):
    """The engine must hand the policy the *decoding* slot count
    (``sched.slots``), never total occupancy: three long prompts
    chunk-prefilling together with nothing decoding see a count of 0
    every tick, so the slo budget stays unlimited and all three finish
    prefill on the same tick."""
    calls = _spy_budget(monkeypatch)
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    serve = ServeConfig(max_slots=3, max_seq=32, block_size=8,
                        prefill_chunk=8, scheduler="slo",
                        compute_dtype=jnp.float32,
                        cache_dtype=jnp.float32)
    reqs = [Request(uid=u, prompt=[(u * 31 + k) % 250 + 1
                                   for k in range(20)],
                    max_new_tokens=4) for u in range(3)]
    eng = ContinuousEngine(params, cfg, serve)
    fin, _ = eng.run(reqs, max_burst=1)
    assert len(fin) == 3
    # 20-token prompts, 8-token chunks: three pure-prefill ticks, each
    # reporting zero decoding slots (budget None -> all slots advance)
    assert calls[:3] == [0, 0, 0]
    # uncapped prefill: all three start decoding on the same tick —
    # counting prefilling slots would have throttled them to one chunk
    # per tick and staggered the starts (calls ramping 1, 2, 3)
    assert calls[3] == 3
    assert set(calls[3:]) == {3}


def test_slo_budget_counts_decoding_slots_only(monkeypatch):
    """A slot decoding next to a slot still chunk-prefilling must be
    reported as ONE decoding slot — reporting total occupancy (2 here)
    was the bug this pins against."""
    calls = _spy_budget(monkeypatch)
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    serve = ServeConfig(max_slots=2, max_seq=32, block_size=8,
                        prefill_chunk=8, scheduler="slo",
                        compute_dtype=jnp.float32,
                        cache_dtype=jnp.float32)
    reqs = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=10),
            Request(uid=1, prompt=list(range(5, 25)), max_new_tokens=6)]
    eng = ContinuousEngine(params, cfg, serve)
    fin, _ = eng.run(reqs, max_burst=1)
    assert len(fin) == 2
    # tick 1: both admitted, nothing decoding yet; uid 0 (3-token
    # prompt) finishes its single chunk and starts decoding.  Ticks
    # 2-3: uid 1 still chunk-prefilling while uid 0 decodes, so the
    # policy must see 1 — not 2, the occupied-slot count.
    assert calls[:3] == [0, 1, 1]
    # once uid 1 starts too, the count reaches the full pool
    assert 2 in calls
    assert max(calls) == 2


# ------------------------------------------------------------- fifo pin

@pytest.mark.parametrize("block_size", [None, 8])
def test_fifo_pin_token_identical_to_plain_requests(block_size):
    """PR 6 behavior preservation: under ``scheduler="fifo"`` the
    engine must admit in strict arrival order and generate exactly the
    tokens it generates for the same prompts with no priority/deadline
    fields set — the new knobs are invisible until a policy uses
    them."""
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    serve = ServeConfig(max_slots=2, max_seq=32, block_size=block_size,
                        compute_dtype=jnp.float32,
                        cache_dtype=jnp.float32, scheduler="fifo")
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [10, 11, 12]]
    plain = [Request(uid=i, prompt=p, max_new_tokens=5)
             for i, p in enumerate(prompts)]
    spiced = [Request(uid=i, prompt=p, max_new_tokens=5,
                      priority=(7 - i) % 3, deadline_ms=float(1 + i))
              for i, p in enumerate(prompts)]
    eng = ContinuousEngine(params, cfg, serve)
    fin_a, _ = eng.run(plain, temperature=0.7, seed=3)
    fin_b, _ = eng.run(spiced, temperature=0.7, seed=3)
    assert [f.request.uid for f in fin_a] == [f.request.uid for f in fin_b]
    for a, b in zip(fin_a, fin_b):
        assert a.tokens == b.tokens
    # strict arrival admission: admitted_at is monotone in uid order
    admits = [f.admitted_at for f in
              sorted(fin_b, key=lambda f: f.request.uid)]
    assert admits == sorted(admits)
