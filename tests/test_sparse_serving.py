"""Block-sparse serving path: pruned model -> kernel plans -> exact
agreement with the dense forward, with real tile-skip fractions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.models import transformer as T
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)
from repro.serve.sparse import (flop_savings, pack_model, pack_projection,
                                sparse_apply_mlp, sparse_linear)


@pytest.fixture(scope="module")
def pruned():
    # dims chosen as multiples of the kernel block (128)
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=32)
    cfg = ModelConfig(name="sp", d_model=128, vocab=256,
                      vocab_pad_multiple=16,
                      pattern=(LayerSpec(attn, MLPSpec(d_ff=256)),),
                      n_periods=2, scan_layers=False, remat=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batches = [jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0,
                                  cfg.vocab) for i in range(2)]
    art = run_ranking_controller(params, cfg, batches)
    res = run_pruning_controller(params, cfg, art, 0.75,
                                 category="unstructured",
                                 selector="wanda_block")
    return res.params, res.cfg, batches[0]


def test_pack_model_finds_skippable_tiles(pruned):
    params, cfg, _ = pruned
    packed = pack_model(params, cfg, block=16)
    assert packed, "no projections packed"
    sav = flop_savings(packed)
    assert 0.3 < sav <= 0.95  # block=16 matches the wanda_block mask tile       # wanda_block at p=0.75 leaves zero tiles


def test_sparse_linear_matches_dense(pruned):
    params, cfg, _ = pruned
    w = params["blocks"][0]["mlp"]["up"]
    packed = pack_projection(w, block=16)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, w.shape[0]))
    y_sparse = sparse_linear(x, w, packed, interpret=True)
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(x @ w),
                               rtol=2e-5, atol=2e-5)


def test_sparse_mlp_matches_dense(pruned):
    params, cfg, toks = pruned
    packed = pack_model(params, cfg, block=16)
    spec = cfg.layer(0).ffn
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
    from repro.models.layers import apply_mlp
    y_dense = apply_mlp(params["blocks"][0]["mlp"], spec, x)
    y_sparse = sparse_apply_mlp(params["blocks"][0], spec, x, packed,
                                layer=0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


def test_non_tileable_projection_returns_none():
    w = jnp.ones((100, 200))       # not multiples of 128
    assert pack_projection(w, block=16) is None
