"""docs/ ↔ code sync: the recipe schema reference must name every
dataclass field and every registered plug-in, the serving guide
must name every ServeConfig field, every gateway wire field, and every
registered scheduler policy, the quantization guide must name every
quant mode and knob, and the benchmarks guide must name every baseline
gate and entry point — so the docs cannot rot as
fields/selectors/categories/stages/gates are added; README + docs
internal links must resolve."""
import dataclasses
import json
import os
import re

import pytest

from repro.core import pipeline  # noqa: F401 (registers stages)
from repro.core.recipe import (GRANULARITIES, QUANT_MODES, CalibrationSpec,
                               PruneRecipe)
from repro.core.registry import CATEGORIES, SELECTORS, STAGES
from repro.core.sweep import GridSpec
from repro.serve.config import ServeConfig
from repro.serve.gateway.protocol import GenerateRequest
from repro.serve.policies import SCHEDULERS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA_DOC = os.path.join(REPO, "docs", "recipe-schema.md")
SERVING_DOC = os.path.join(REPO, "docs", "serving.md")
QUANT_DOC = os.path.join(REPO, "docs", "quantization.md")
BENCH_DOC = os.path.join(REPO, "docs", "benchmarks.md")


@pytest.fixture(scope="module")
def schema_text():
    assert os.path.exists(SCHEMA_DOC), "docs/recipe-schema.md is missing"
    with open(SCHEMA_DOC) as f:
        return f.read()


def _codes(text):
    """All `inline code` spans — fields/names must appear as code.
    Fenced ``` blocks are stripped first: a fence's backticks would
    otherwise pair up with inline spans and swallow them."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return set(re.findall(r"`([^`]+)`", text))


@pytest.mark.parametrize("cls", [PruneRecipe, CalibrationSpec, GridSpec])
def test_every_dataclass_field_documented(schema_text, cls):
    codes = _codes(schema_text)
    missing = [f.name for f in dataclasses.fields(cls)
               if f.name not in codes]
    assert not missing, (f"{cls.__name__} fields missing from "
                         f"docs/recipe-schema.md: {missing}")


@pytest.fixture(scope="module")
def serving_text():
    assert os.path.exists(SERVING_DOC), "docs/serving.md is missing"
    with open(SERVING_DOC) as f:
        return f.read()


def test_every_serveconfig_field_documented(serving_text):
    """docs/serving.md is the ServeConfig reference: every dataclass
    field must appear as inline code, so the serving guide cannot rot
    as serving knobs are added."""
    codes = _codes(serving_text)
    missing = [f.name for f in dataclasses.fields(ServeConfig)
               if f.name not in codes]
    assert not missing, (f"ServeConfig fields missing from "
                         f"docs/serving.md: {missing}")


def test_every_gateway_request_field_documented(serving_text):
    """The gateway wire schema (GenerateRequest) is part of the serving
    guide: every wire field must appear as inline code."""
    codes = _codes(serving_text)
    missing = [f.name for f in dataclasses.fields(GenerateRequest)
               if f.name not in codes]
    assert not missing, (f"GenerateRequest wire fields missing from "
                         f"docs/serving.md: {missing}")


def test_every_scheduler_policy_documented(serving_text):
    """Every registered admission policy must be named in the serving
    guide's policy table."""
    codes = _codes(serving_text)
    missing = [n for n in SCHEDULERS.names() if n not in codes]
    assert not missing, (f"scheduler policies missing from "
                         f"docs/serving.md: {missing}")


def test_every_registry_name_documented(schema_text):
    for registry in (SELECTORS, CATEGORIES, STAGES):
        for name in registry.names():
            assert f'"{name}"' in schema_text or f"`{name}`" in schema_text, \
                f"{registry.kind} {name!r} missing from docs/recipe-schema.md"
    for name in GRANULARITIES:
        assert f'"{name}"' in schema_text or f"`{name}`" in schema_text, \
            f"granularity {name!r} missing from docs/recipe-schema.md"


def test_doc_names_no_stale_registry_entries(schema_text):
    """The registry-names section lists only names that still exist."""
    section = schema_text.split("## Registry names", 1)[1]
    documented = {n for n in _codes(section)
                  if re.fullmatch(r"[a-z_]+", n)}
    known = (set(SELECTORS.names()) | set(CATEGORIES.names())
             | set(STAGES.names()) | set(GRANULARITIES)
             | {"cloud", "edge", "mobile"})      # PLATFORMS presets
    stale = {s for s in documented - known if "." not in s}
    assert not stale, f"stale names documented: {sorted(stale)}"


# ------------------------------------------------- quantization.md sync

@pytest.fixture(scope="module")
def quant_text():
    assert os.path.exists(QUANT_DOC), "docs/quantization.md is missing"
    with open(QUANT_DOC) as f:
        return f.read()


def test_quant_doc_covers_modes_and_knobs(quant_text):
    """Every QUANT_MODES value and every quant knob — the recipe field,
    the serve field, and the CLI flag — must appear in the quantization
    guide as inline code."""
    codes = _codes(quant_text)
    missing = [v for v in QUANT_MODES
               if v not in codes and f'"{v}"' not in quant_text]
    assert not missing, f"quant modes missing from docs: {missing}"
    for knob in ("quant", "PruneRecipe.quant", "ServeConfig.quant",
                 "--quant", "quantize_tiles", "quant_bytes"):
        assert any(knob in c for c in codes), \
            f"{knob!r} missing from docs/quantization.md"


def test_quant_fields_exist_in_dataclasses():
    """The knobs the doc describes are real fields with QUANT_MODES
    semantics."""
    assert "quant" in {f.name for f in dataclasses.fields(PruneRecipe)}
    assert "quant" in {f.name for f in dataclasses.fields(ServeConfig)}
    assert "quant" in {f.name for f in dataclasses.fields(GridSpec)}
    assert PruneRecipe(arch="llama3-8b", p=0.5).quant in QUANT_MODES


# --------------------------------------------------- benchmarks.md sync

@pytest.fixture(scope="module")
def bench_text():
    assert os.path.exists(BENCH_DOC), "docs/benchmarks.md is missing"
    with open(BENCH_DOC) as f:
        return f.read()


def test_every_baseline_gate_documented(bench_text):
    """Every metric key gated in benchmarks/baseline.json must be named
    in docs/benchmarks.md — gates cannot be added silently."""
    with open(os.path.join(REPO, "benchmarks", "baseline.json")) as f:
        baseline = json.load(f)
    codes = _codes(bench_text)
    missing = [k for k in baseline["metrics"] if k not in codes]
    assert not missing, \
        f"baseline.json gates missing from docs/benchmarks.md: {missing}"


def test_every_benchmark_entry_point_documented(bench_text):
    """Every benchmarks/*.py module must be named in the guide."""
    codes = _codes(bench_text)
    mods = [n for n in sorted(os.listdir(os.path.join(REPO, "benchmarks")))
            if n.endswith(".py") and not n.startswith("_")]
    missing = [m for m in mods if m not in codes]
    assert not missing, \
        f"benchmark modules missing from docs/benchmarks.md: {missing}"


# ------------------------------------------------------------ doc links

def _md_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    for name in sorted(os.listdir(docs)):
        if name.endswith(".md"):
            files.append(os.path.join(docs, name))
    return files


def test_markdown_relative_links_resolve():
    """Every relative link in README + docs/ points at a real file
    (external http(s) links and badge endpoints are skipped)."""
    broken = []
    for path in _md_files():
        with open(path) as f:
            text = f.read()
        for target in re.findall(r"\[[^\]]*\]\(([^)#]+)(?:#[^)]*)?\)", text):
            if target.startswith(("http://", "https://", "../../")):
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                broken.append(f"{os.path.relpath(path, REPO)} -> {target}")
    assert not broken, f"broken relative links: {broken}"
