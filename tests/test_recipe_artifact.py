"""Declarative pipeline API: PruneRecipe JSON round-trip, PrunedArtifact
save/load fidelity, and prune -> save -> load -> generate producing
token-identical output vs the in-memory path (dense + sparse engines)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import iter_paths
from repro.core.artifact import PrunedArtifact
from repro.core.pipeline import MosaicPipeline
from repro.core.prune_controller import (Platform, run_pruning_controller,
                                         select_category)
from repro.core.rank_controller import profile_model
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.core.registry import CATEGORIES, SELECTORS, STAGES
from repro.models import transformer as T
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig, config_from_dict,
                                config_to_dict)
from repro.serve.engine import Engine
from repro.serve.sparse import pack_model_with_report
from tests.conftest import small_config


def tileable_config() -> ModelConfig:
    # dims multiples of the block (16) so the pack stage has real plans
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=32)
    return ModelConfig(name="recipe-test", d_model=128, vocab=256,
                       vocab_pad_multiple=16,
                       pattern=(LayerSpec(attn, MLPSpec(d_ff=256)),),
                       n_periods=2, scan_layers=False, remat=False)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    cfg = tileable_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.6, category="composite",
                         selector="wanda_block", align_channels=16,
                         block=16,
                         calibration=CalibrationSpec(4, 2, 16))
    art = MosaicPipeline(recipe).run(params, cfg)
    d = str(tmp_path_factory.mktemp("bundle"))
    art.save(d)
    return art, PrunedArtifact.load(d)


# ------------------------------------------------------------- recipe

def test_recipe_json_roundtrip():
    r = PruneRecipe(arch="llama3-8b", p=0.55, category=None,
                    granularity="layer", selector="sparsegpt",
                    structured_share=0.3, align_heads=2, align_channels=32,
                    platform="edge", block=64,
                    calibration=CalibrationSpec(16, 4, 128, seed=7),
                    stages=("rank", "plan", "prune"))
    assert PruneRecipe.from_json(r.to_json()) == r
    # dict round-trip through real JSON (tuples become lists)
    assert PruneRecipe.from_dict(json.loads(json.dumps(r.to_dict()))) == r


def test_recipe_validation():
    with pytest.raises(ValueError):
        PruneRecipe(arch="a", p=1.2)
    with pytest.raises(ValueError):
        PruneRecipe(arch="a", p=0.5, granularity="per-weight")
    with pytest.raises(ValueError):
        PruneRecipe(arch="a", p=0.5, structured_share=1.5)
    with pytest.raises(ValueError):
        PruneRecipe.from_dict({"arch": "a", "p": 0.5, "bogus": 1})


def test_recipe_file_roundtrip(tmp_path):
    r = PruneRecipe(arch="gemma-2b", p=0.4)
    path = str(tmp_path / "r.json")
    r.save(path)
    assert PruneRecipe.load(path) == r


def test_config_dict_roundtrip():
    cfg = small_config(moe=True, mamba=True)
    through_json = json.loads(json.dumps(config_to_dict(cfg)))
    assert config_from_dict(through_json) == cfg


# ----------------------------------------------------------- registry

def test_registries_populated():
    for name in ("magnitude", "wanda", "wanda_block", "sparsegpt"):
        assert name in SELECTORS
    for name in ("unstructured", "structured", "composite"):
        assert name in CATEGORIES
    for name in ("rank", "plan", "prune", "pack", "report"):
        assert name in STAGES


def test_unknown_stage_fails_fast():
    r = PruneRecipe(arch="a", p=0.5, stages=("rank", "quantize"))
    with pytest.raises(KeyError):
        MosaicPipeline(r)


def test_plan_without_rank_raises():
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    r = PruneRecipe(arch="a", p=0.5, stages=("plan",))
    with pytest.raises(RuntimeError):
        MosaicPipeline(r).run(params, cfg)


# ----------------------------------------------------------- category

def test_select_category_uses_structured_share():
    plat = Platform("mid", 8 << 30)
    dense = 10 << 30
    # share 0.5 at p=0.5 -> composite fits (7.5G); share 0.2 -> 9G > 8G
    assert select_category(plat, dense, 0.5, structured_share=0.5) == \
        "composite"
    assert select_category(plat, dense, 0.5, structured_share=0.2) == \
        "structured"


# ------------------------------------------------------------ artifact

def test_artifact_roundtrip_fields(artifact):
    art, loaded = artifact
    assert loaded.recipe == art.recipe
    assert loaded.cfg == art.cfg
    assert loaded.targets == pytest.approx(art.targets)
    assert set(loaded.packed) == set(art.packed)
    for k, p in art.packed.items():
        lp = loaded.packed[k]
        assert lp.block == p.block and lp.density == p.density
        np.testing.assert_array_equal(np.asarray(lp.counts),
                                      np.asarray(p.counts))
        np.testing.assert_array_equal(np.asarray(lp.indices),
                                      np.asarray(p.indices))
    for (p1, l1), (p2, l2) in zip(iter_paths(art.params),
                                  iter_paths(loaded.params)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert loaded.report["category"] == "composite"
    assert loaded.report["prune_seconds"] > 0
    json.dumps(loaded.report)           # report stays JSON-clean


def test_artifact_pack_report_exposes_skips(artifact):
    art, loaded = artifact
    pk = loaded.report["pack"]
    # the o projection folds to (n_q, head_dim*d_model): K not tileable
    assert pk["n_skipped"] >= 1
    assert pk["skipped_params"] > 0
    assert {s["reason"] for s in pk["skipped"]} <= {"non-tileable", "expert"}
    assert pk["n_packed"] == len(loaded.packed)


def test_load_rejects_non_artifact(tmp_path):
    with pytest.raises(FileNotFoundError):
        PrunedArtifact.load(str(tmp_path / "nope"))


# ----------------------------------- token-identical serve (the payoff)

def _generate(params, cfg, packed, prompt, n_new=8):
    eng = Engine(params, cfg, max_seq=prompt.shape[1] + n_new,
                 compute_dtype=jnp.float32, cache_dtype=jnp.float32,
                 packed=packed)
    return np.asarray(eng.generate(prompt, n_new))


def test_loaded_artifact_serves_token_identical(artifact):
    art, loaded = artifact
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                art.cfg.vocab)
    # dense engines: in-memory pruned model vs loaded artifact
    dense_mem = _generate(art.params, art.cfg, None, prompt)
    dense_loaded = _generate(loaded.params, loaded.cfg, None, prompt)
    np.testing.assert_array_equal(dense_mem, dense_loaded)
    # sparse engines (interpret mode): saved plans vs in-memory plans,
    # and sparse-from-artifact vs dense-in-memory
    sparse_mem = _generate(art.params, art.cfg, art.packed, prompt)
    sparse_loaded = _generate(loaded.params, loaded.cfg, loaded.packed,
                              prompt)
    np.testing.assert_array_equal(sparse_mem, sparse_loaded)
    np.testing.assert_array_equal(dense_mem, sparse_loaded)


def test_engine_from_artifact_uses_saved_plans(artifact):
    _, loaded = artifact
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                loaded.cfg.vocab)
    eng = Engine.from_artifact(loaded, max_seq=16,
                               compute_dtype=jnp.float32,
                               cache_dtype=jnp.float32)
    out = np.asarray(eng.generate(prompt, 4))
    ref = _generate(loaded.params, loaded.cfg, loaded.packed, prompt,
                    n_new=4)
    np.testing.assert_array_equal(out, ref)


# ------------------------------------------------------------- shims

def test_controller_shim_matches_pipeline():
    cfg = small_config(moe=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batches = [jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0,
                                  cfg.vocab) for i in range(2)]
    ra = profile_model(params, cfg, batches)
    res = run_pruning_controller(params, cfg, ra, 0.5, category="composite")
    recipe = PruneRecipe(arch=cfg.name, p=0.5, category="composite",
                         stages=("plan", "prune", "report"))
    art = MosaicPipeline(recipe).run(params, cfg, rank_artifact=ra)
    assert res.category == art.report["category"] == "composite"
    assert res.cfg == art.cfg
    for (p1, l1), (p2, l2) in zip(iter_paths(res.params),
                                  iter_paths(art.params)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_pack_model_with_report_counts():
    cfg = small_config()            # d_model=64, d_ff=128: tileable @16
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    packed, report = pack_model_with_report(params, cfg, block=16)
    assert report["n_packed"] == len(packed)
    assert report["n_packed"] + report["n_skipped"] > 0
    assert report["packed_params"] > 0
    total = {f.name for f in dataclasses.fields(PruneRecipe)}
    assert "block" in total         # recipe carries the pack block size
