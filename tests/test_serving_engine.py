"""Continuous-batching engine: scheduler slot lifecycle, token-for-token
agreement with the static Engine, and the block-sparse serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)
from repro.serve.batching import ContinuousEngine, latency_percentiles
from repro.serve.engine import Engine
from repro.serve.scheduler import Request, Scheduler


# ------------------------------------------------------------- scheduler
# pure host-side bookkeeping: no jax, instant

def test_scheduler_fifo_admission_and_slot_reuse():
    s = Scheduler(max_slots=2, max_seq=32)
    for i in range(4):
        s.submit(Request(uid=i, prompt=[1, 2, 3], max_new_tokens=2))
    slots = s.admissions()
    assert [sl.request.uid for sl in slots] == [0, 1]
    assert s.admissions() == []                     # pool full
    for sl in slots:
        s.started(sl, first_token=7)
    # one decode tick finishes both (budget 2: prefill token + 1)
    s.decoded({sl.index: 9 for sl in slots})
    assert len(s.finished) == 2
    assert not s.slots
    # freed slots are reused by the next FIFO pair
    slots2 = s.admissions()
    assert [sl.request.uid for sl in slots2] == [2, 3]
    assert {sl.index for sl in slots2} == {sl.index for sl in slots}


def test_scheduler_eos_and_reject():
    s = Scheduler(max_slots=1, max_seq=8)
    s.submit(Request(uid=0, prompt=list(range(8)), max_new_tokens=4))
    assert s.rejected and not s.queue               # prompt + 1 > max_seq
    s.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=50, eos_id=5))
    (sl,) = s.admissions()
    s.started(sl, first_token=3)
    s.decoded({sl.index: 5})                        # EOS
    assert s.finished[-1].reason == "eos"
    assert s.finished[-1].tokens == [3, 5]
    # cache_full: budget larger than the cache can hold
    s.submit(Request(uid=2, prompt=[1, 2], max_new_tokens=50))
    (sl,) = s.admissions()
    s.started(sl, first_token=3)
    for t in range(10):
        if sl.index not in s.slots:
            break
        s.decoded({sl.index: 9})
    assert s.finished[-1].reason == "cache_full"
    assert s.finished[-1].request.uid == 2


def test_scheduler_ignores_stale_slot_tokens():
    # tokens decoded past a finished slot (mid-burst waste) are dropped
    s = Scheduler(max_slots=1, max_seq=32)
    s.submit(Request(uid=0, prompt=[1], max_new_tokens=2))
    (sl,) = s.admissions()
    s.started(sl, first_token=4)
    s.decoded({sl.index: 5})
    assert len(s.finished) == 1
    s.decoded({sl.index: 6})                        # stale: no crash, no-op
    assert s.finished[0].tokens == [4, 5]


# ---------------------------------------------------------------- engine

@pytest.fixture(scope="module")
def served():
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
    cfg = ModelConfig(name="srv", d_model=64, vocab=256,
                      vocab_pad_multiple=16,
                      pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),),
                      n_periods=2, scan_layers=False, remat=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_vector_cache_index_matches_scalar(served):
    params, cfg = served
    s_max = 32
    pool = T.init_cache_pool(cfg, 2, s_max, jnp.float32)
    lens = [5, 9]
    toks = [jax.random.randint(jax.random.PRNGKey(i + 1), (1, n), 0, 256)
            for i, n in enumerate(lens)]
    for slot, t in enumerate(toks):
        row = T.init_cache(cfg, 1, s_max, jnp.float32)
        _, row, _ = T.forward(params, cfg, t, cache=row,
                              cache_index=jnp.int32(0),
                              compute_dtype=jnp.float32)
        pool = T.write_cache_slot(pool, row, slot)
    new = jnp.array([[7], [11]], jnp.int32)
    lo_vec, _, _ = T.forward(params, cfg, new, cache=pool,
                             cache_index=jnp.asarray(lens, jnp.int32),
                             compute_dtype=jnp.float32)
    for i, (n, t) in enumerate(zip(lens, toks)):
        c = T.init_cache(cfg, 1, s_max, jnp.float32)
        _, c, _ = T.forward(params, cfg, t, cache=c,
                            cache_index=jnp.int32(0),
                            compute_dtype=jnp.float32)
        lo_ref, _, _ = T.forward(params, cfg, new[i:i + 1], cache=c,
                                 cache_index=jnp.int32(n),
                                 compute_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(lo_vec[i]),
                                      np.asarray(lo_ref[0]))


def test_cache_pool_requires_unrolled(served):
    _, cfg = served
    with pytest.raises(ValueError):
        T.init_cache_pool(cfg.replace(scan_layers=True), 2, 16)


def test_continuous_hybrid_needs_unpadded_prefill():
    # padded prefill would integrate pad tokens into the SSM state, so
    # hybrid configs are rejected unless prefills are unpadded — and with
    # prefill_multiple=1 the hybrid engine matches the static engine
    from tests.conftest import small_config
    cfg = small_config(mamba=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ContinuousEngine(params, cfg, max_slots=2, max_seq=32)
    ce = ContinuousEngine(params, cfg, max_slots=2, max_seq=32,
                          compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32, prefill_multiple=1)
    eng = Engine(params, cfg, 32, compute_dtype=jnp.float32,
                 cache_dtype=jnp.float32)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, (n,)).tolist(),
                    max_new_tokens=5)
            for i, n in enumerate([6, 9])]
    finished, _ = ce.run(reqs)
    for f in finished:
        p = jnp.asarray([f.request.prompt], jnp.int32)
        ref = eng.generate(p, 5)[0, p.shape[1]:].tolist()
        assert f.tokens == ref, f"uid {f.request.uid} diverged"


def test_cache_full_uses_last_kv_position():
    # a budget larger than the cache stops exactly when the pool is full:
    # prompt s0 + one prefill-sampled token + (max_seq - s0) decode writes
    s = Scheduler(max_slots=1, max_seq=8)
    s.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=50))
    (sl,) = s.admissions()
    s.started(sl, first_token=3)
    while sl.index in s.slots:
        s.decoded({sl.index: 9})
    f = s.finished[-1]
    assert f.reason == "cache_full"
    assert len(f.tokens) == 8 - 2 + 1       # max_seq - s0 + 1


def test_continuous_matches_static_mixed_lengths(served):
    params, cfg = served
    eng = Engine(params, cfg, 64, compute_dtype=jnp.float32,
                 cache_dtype=jnp.float32)
    ce = ContinuousEngine(params, cfg, max_slots=3, max_seq=64,
                          compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    n_new = 10
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, (n,)).tolist(),
                    max_new_tokens=n_new)
            for i, n in enumerate([7, 13, 5, 20, 9])]   # 5 reqs, 3 slots
    finished, stats = ce.run(reqs)
    assert len(finished) == len(reqs)
    assert stats.prefills == len(reqs)                  # slots were reused
    for f in finished:
        p = jnp.asarray([f.request.prompt], jnp.int32)
        ref = eng.generate(p, n_new)[0, p.shape[1]:].tolist()
        assert f.tokens == ref, f"uid {f.request.uid} diverged"
    lat = latency_percentiles(finished)
    assert lat["p99"] >= lat["p50"] > 0


def test_continuous_eos_mid_burst(served):
    params, cfg = served
    eng = Engine(params, cfg, 64, compute_dtype=jnp.float32,
                 cache_dtype=jnp.float32)
    ce = ContinuousEngine(params, cfg, max_slots=2, max_seq=64,
                          compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 256, (6,)).tolist()
    ref = eng.generate(jnp.asarray([prompt], jnp.int32), 12)[0, 6:].tolist()
    eos = ref[4]
    stop = ref.index(eos) + 1          # first occurrence wins
    finished, _ = ce.run([Request(uid=0, prompt=prompt, max_new_tokens=12,
                                  eos_id=eos)])
    assert finished[0].reason == "eos"
    assert finished[0].tokens == ref[:stop]


@pytest.fixture(scope="module")
def pruned_served():
    from repro.core.prune_controller import run_pruning_controller
    from repro.core.rank_controller import run_ranking_controller
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=32)
    cfg = ModelConfig(name="sp", d_model=128, vocab=256,
                      vocab_pad_multiple=16,
                      pattern=(LayerSpec(attn, MLPSpec(d_ff=256)),),
                      n_periods=2, scan_layers=False, remat=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batches = [jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0,
                                  cfg.vocab) for i in range(2)]
    art = run_ranking_controller(params, cfg, batches)
    res = run_pruning_controller(params, cfg, art, 0.75,
                                 category="unstructured",
                                 selector="wanda_block")
    return res.params, res.cfg


def test_sparse_engine_matches_dense_interpret(pruned_served):
    from repro.serve.sparse import flop_savings, pack_model
    params, cfg = pruned_served
    packed = pack_model(params, cfg, block=16)
    assert packed and flop_savings(packed) > 0.3
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, (n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate([6, 11, 4])]
    kw = dict(max_slots=2, max_seq=48, compute_dtype=jnp.float32,
              cache_dtype=jnp.float32)
    dense, _ = ContinuousEngine(params, cfg, **kw).run(reqs)
    sparse, _ = ContinuousEngine(params, cfg, packed=packed,
                                 interpret=True, **kw).run(reqs)
    for d, s in zip(dense, sparse):
        assert d.tokens == s.tokens, f"uid {d.request.uid} diverged"
