"""Data pipeline, optimizer, checkpointing, fault tolerance, LoRA, quant."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.core import lora as LO
from repro.core.quant import quantize_array, dequantize_array, quantize_model
from repro.data.pipeline import Prefetcher, SyntheticCorpus
from repro.distributed.fault import (PreemptionHandler, StragglerMonitor,
                                     with_retries)
from repro.models import transformer as T
from repro.train import optimizer as OPT
from tests.conftest import small_config


# ------------------------------------------------------------------ data

def test_corpus_deterministic_and_learnable():
    c = SyntheticCorpus(256, seed=1)
    b1 = c.batch(0, 4, 32)
    b2 = c.batch(0, 4, 32)
    np.testing.assert_array_equal(b1, b2)
    assert not np.array_equal(b1, c.batch(1, 4, 32))
    # Markov structure: successor always from the successor table
    for row in b1:
        for t in range(len(row) - 1):
            assert row[t + 1] in c.successors[row[t]]


def test_prefetcher_preserves_order():
    it = iter([(i, i) for i in range(10)])
    out = list(Prefetcher(it, depth=3))
    assert out == [(i, i) for i in range(10)]


# --------------------------------------------------------------- optimizer

def test_adamw_decreases_quadratic_loss():
    cfg = OPT.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = OPT.init_opt(params, cfg)
    for _ in range(60):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = OPT.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


@pytest.mark.parametrize("factored", [False, True])
def test_opt_state_shapes(factored):
    cfg = OPT.OptConfig(factored=factored)
    params = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
    st_ = OPT.init_opt(params, cfg)
    g = jax.tree.map(lambda x: x * 0.1, params)
    new_p, new_s, stats = OPT.apply_updates(params, g, st_, cfg)
    assert new_p["w"].shape == (8, 16)
    assert float(stats["grad_norm"]) > 0
    if factored:
        assert new_s["v"]["w"]["row"].shape == (8,)
        assert new_s["v"]["w"]["col"].shape == (16,)


def test_schedule_warmup_and_decay():
    cfg = OPT.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(OPT.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(OPT.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(OPT.schedule(cfg, jnp.int32(100))) == pytest.approx(
        cfg.min_lr_frac, abs=1e-3)


def test_grad_clip():
    g = {"w": jnp.ones((4,)) * 10}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_retention():
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in (1, 2, 3):
            mgr.save(step, params, blocking=True)
        assert mgr.all_steps() == [2, 3]
        like = jax.tree.map(jnp.zeros_like, params)
        restored = mgr.restore(like)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_atomic():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        tree = {"x": jnp.arange(1000.0)}
        mgr.save(7, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7
        assert not any(n.endswith(".tmp") for n in os.listdir(d))
        assert mgr.meta()["step"] == 7


# ------------------------------------------------------------------ fault

def test_preemption_handler():
    h = PreemptionHandler().install()
    assert not h.should_stop
    h.trigger()
    assert h.should_stop
    h.uninstall()


def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(threshold=2.0, warmup=2)
    for i in range(10):
        m.record(i, 0.1)
    assert m.record(10, 0.5)                   # 5x EMA -> straggler
    assert not m.record(11, 0.11)
    assert len(m.flagged) == 1
    # straggler did not poison the watermark
    assert m.ema == pytest.approx(0.1, rel=0.15)


def test_with_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise IOError("transient")
        return "ok"
    assert with_retries(flaky, n_retries=3, backoff=0.0)() == "ok"
    assert calls["n"] == 3


# ------------------------------------------------------------- lora/quant

def test_lora_zero_init_is_identity():
    cfg = small_config(moe=True, mamba=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    lo0, _, _ = T.forward(params, cfg, toks, compute_dtype=jnp.float32)
    ad = LO.init_lora(jax.random.PRNGKey(2), params, cfg, rank=4)
    merged = LO.merge_lora(params, cfg, ad, rank=4)
    lo1, _, _ = T.forward(merged, cfg, toks, compute_dtype=jnp.float32)
    np.testing.assert_allclose(lo0, lo1, atol=1e-6)


def test_lora_merge_respects_masks():
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    ad = LO.init_lora(jax.random.PRNGKey(2), params, cfg, rank=4)
    # make B nonzero so the delta is nontrivial
    ad = jax.tree.map(lambda x: x + 0.1, ad)
    from repro.core.registry import projections
    masks = {}
    for proj in projections(cfg):
        from repro.common.tree import tree_get
        w = tree_get(params, proj.path)
        masks[proj.key] = jnp.zeros(w.shape, bool)   # everything pruned
    merged = LO.merge_lora(params, cfg, ad, rank=4, masks=masks)
    for proj in projections(cfg):
        from repro.common.tree import tree_get
        np.testing.assert_array_equal(
            np.asarray(tree_get(merged, proj.path)),
            np.asarray(tree_get(params, proj.path)))


@given(st.integers(2, 8), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_quant_roundtrip_error_bounded(bits, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 32))
    q, scale, shape, pad = quantize_array(w, bits, group=32)
    back = dequantize_array(q, scale, shape, pad)
    maxq = 2 ** (bits - 1) - 1
    # error bounded by half a quantisation step per group
    step = np.asarray(jnp.max(jnp.abs(w)) / maxq)
    assert float(jnp.abs(back - w).max()) <= step * 0.5 + 1e-6


def test_quantize_model_compression_ratio():
    cfg = small_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    _, stats = quantize_model(params, cfg, bits=4, group=64)
    assert 3.0 < stats["compression"] <= 4.0
