"""Per-expert block-sparse serving: MoE expert weights are planned (not
skipped) by the pack stage, the MoE dispatch routes each expert's slots
through the block-sparse kernels — the grouped one-launch-for-all-
experts kernel by default, the per-expert launch loop as the
``group_experts=False`` fallback — and expert plans round-trip through
the PrunedArtifact bundle with their ``group`` flag. Grouped, loop, and
dense are all token-identical in interpret mode, for both engines, both
in-memory and after save/load.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.artifact import PrunedArtifact
from repro.core.pipeline import MosaicPipeline
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.models import transformer as T
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig, MoESpec)
from repro.serve.batching import ContinuousEngine
from repro.serve.engine import Engine
from repro.serve.scheduler import Request
from repro.serve.sparse import (PackedExpertProjection, flop_savings,
                                pack_expert_projection, plans_from_host,
                                plans_to_host)

BLOCK = 16


def moe_config() -> ModelConfig:
    # every projection fold a multiple of BLOCK, incl. per-expert folds
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
    return ModelConfig(
        name="moe-sparse-test", d_model=64, vocab=256, vocab_pad_multiple=16,
        pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),
                 LayerSpec(attn, MoESpec(n_experts=4, top_k=2, d_ff=64))),
        n_periods=1, scan_layers=False, remat=False)


@pytest.fixture(scope="module")
def moe_artifact(tmp_path_factory):
    """prune (wanda_block, unstructured) -> save -> load."""
    cfg = moe_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.65, category="unstructured",
                         selector="wanda_block", block=BLOCK,
                         calibration=CalibrationSpec(4, 2, 16))
    art = MosaicPipeline(recipe).run(params, cfg)
    d = str(tmp_path_factory.mktemp("moe-bundle"))
    art.save(d)
    return art, PrunedArtifact.load(d)


# ------------------------------------------------------------------ pack

def test_pack_report_has_no_expert_skips(moe_artifact):
    art, _ = moe_artifact
    pk = art.report["pack"]
    assert {s["reason"] for s in pk["skipped"]} <= {"non-tileable"}
    assert pk["n_expert_packed"] == 3          # gate/up/down of the MoE layer
    expert_plans = {k: p for k, p in art.packed.items()
                    if isinstance(p, PackedExpertProjection)}
    assert set(expert_plans) == {(1, "gate"), (1, "up"), (1, "down")}
    for p in expert_plans.values():
        assert p.n_experts == 4
        assert p.counts.shape[0] == 4 and p.indices.ndim == 3
        # wanda_block at p=0.65 leaves real zero tiles in every expert
        assert all(0.0 < d < 1.0 for d in p.densities)
        assert p.group                     # grouped kernel is the default
    assert flop_savings(art.packed) > 0.2


def test_flop_savings_counts_each_expert(moe_artifact):
    """Expert stacks contribute one term per expert, not one per stack."""
    art, _ = moe_artifact
    expected = []
    for p in art.packed.values():
        if isinstance(p, PackedExpertProjection):
            expected.extend(1.0 - d for d in p.densities)
        else:
            expected.append(1.0 - p.density)
    assert flop_savings(art.packed) == pytest.approx(np.mean(expected))
    # a lopsided stack: stack mean must not drown the sparse expert
    lop = {(0, "up"): PackedExpertProjection(
        counts=jnp.zeros((2, 1), jnp.int32),
        indices=jnp.zeros((2, 1, 1), jnp.int32), block=16,
        density=0.5, densities=(0.0, 1.0))}
    assert flop_savings(lop) == pytest.approx(0.5)


def test_group_experts_recipe_knob_reaches_plans(moe_artifact):
    """recipe.group_experts=False packs loop-mode plan stacks (and the
    flag survives the host round-trip)."""
    art, _ = moe_artifact
    recipe = art.recipe.replace(group_experts=False)
    cfg = moe_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    loop_art = MosaicPipeline(recipe).run(params, cfg)
    assert loop_art.report["pack"]["group_experts"] is False
    stacks = [p for p in loop_art.packed.values()
              if isinstance(p, PackedExpertProjection)]
    assert stacks and all(not p.group for p in stacks)
    arrays, meta = plans_to_host(loop_art.packed)
    back = plans_from_host(arrays, meta)
    assert all(not p.group for p in back.values()
               if isinstance(p, PackedExpertProjection))
    # the default artifact's plans say group=True in meta
    _, meta_default = plans_to_host(art.packed)
    assert any(m.get("group") for m in meta_default.values())


def test_pack_expert_projection_non_tileable_returns_none():
    w = jnp.ones((4, 100, 60))                 # per-expert fold not @BLOCK
    assert pack_expert_projection(w, block=BLOCK) is None


def test_expert_plan_padding_is_rectangular():
    # experts with diverging densities still stack (shared max_nnz)
    rng = np.random.default_rng(0)
    w = rng.normal(size=(2, 64, 64))
    w[0, :48, :] = 0.0                         # expert 0 much sparser
    p = pack_expert_projection(jnp.asarray(w), block=BLOCK)
    assert p.indices.shape[0] == 2
    assert p.indices.shape[1:] == p.expert(0).indices.shape
    assert p.densities[0] < p.densities[1]
    # per-expert views agree with independently planned experts
    from repro.serve.sparse import pack_projection
    for e in range(2):
        solo = pack_projection(jnp.asarray(w[e]), block=BLOCK)
        np.testing.assert_array_equal(np.asarray(p.expert(e).counts),
                                      np.asarray(solo.counts))


# ------------------------------------------------------- host round-trip

def test_expert_plans_host_roundtrip(moe_artifact):
    art, loaded = moe_artifact
    arrays, meta = plans_to_host(art.packed)
    back = plans_from_host(arrays, meta)
    assert set(back) == set(art.packed)
    for k, p in art.packed.items():
        b = back[k]
        assert type(b) is type(p)
        assert b.block == p.block and b.density == pytest.approx(p.density)
        np.testing.assert_array_equal(np.asarray(b.counts),
                                      np.asarray(p.counts))
        np.testing.assert_array_equal(np.asarray(b.indices),
                                      np.asarray(p.indices))
        if isinstance(p, PackedExpertProjection):
            assert b.densities == pytest.approx(p.densities)
    # and the artifact bundle preserved the same plans on disk
    for k, p in art.packed.items():
        lp = loaded.packed[k]
        assert type(lp) is type(p)
        np.testing.assert_array_equal(np.asarray(lp.indices),
                                      np.asarray(p.indices))


# -------------------------------------- token-identical serving (payoff)

def test_moe_sparse_engine_token_identical(moe_artifact):
    """Grouped (default) AND per-expert loop, in-memory AND loaded, all
    token-identical to dense through the static engine."""
    art, loaded = moe_artifact
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                art.cfg.vocab)

    def gen(params, cfg, packed, group=None):
        eng = Engine(params, cfg, max_seq=24, compute_dtype=jnp.float32,
                     cache_dtype=jnp.float32, packed=packed,
                     group_experts=group)
        return np.asarray(eng.generate(prompt, 8))

    dense = gen(art.params, art.cfg, None)
    for params, cfg, packed in ((art.params, art.cfg, art.packed),
                                (loaded.params, loaded.cfg, loaded.packed)):
        np.testing.assert_array_equal(dense, gen(params, cfg, packed))
        np.testing.assert_array_equal(
            dense, gen(params, cfg, packed, group=False))


def test_moe_sparse_continuous_engine_token_identical(moe_artifact):
    """Grouped (default) AND per-expert loop, in-memory AND from a
    loaded artifact, all token-identical to dense through the
    continuous-batching engine."""
    art, loaded = moe_artifact
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, (n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate([5, 9, 7])]
    kw = dict(max_slots=2, max_seq=32, compute_dtype=jnp.float32,
              cache_dtype=jnp.float32)
    dense, _ = ContinuousEngine(art.params, art.cfg, **kw).run(reqs)
    variants = {
        "mem-grouped": ContinuousEngine(art.params, art.cfg,
                                        packed=art.packed, **kw),
        "mem-loop": ContinuousEngine(art.params, art.cfg,
                                     packed=art.packed,
                                     group_experts=False, **kw),
        "load-grouped": ContinuousEngine.from_artifact(loaded, **kw),
        "load-loop": ContinuousEngine.from_artifact(
            loaded, group_experts=False, **kw),
    }
    for label, eng in variants.items():
        finished, _ = eng.run(reqs)
        for d, s in zip(dense, finished):
            assert d.tokens == s.tokens, \
                f"uid {d.request.uid} diverged ({label})"
