"""Ragged (MegaBlocks-style) MoE dispatch: property-test harness for the
packed-buffer layout, capacity clamping at tiny decode batches, path
equivalence (ragged == grouped == loop == dense token-for-token), and a
determinism pin — packing order must not change sampled tokens, and the
occupancy-dependent dispatch must not retrace across ticks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.specs import MoESpec
from repro.serve.sparse import (RAGGED_TOKENS_MAX, pack_expert_projection,
                                plans_from_host, plans_to_host,
                                sparse_apply_moe)
from tests._hypothesis_compat import given, settings, st


def test_ragged_tile_height_matches_kernel():
    """The dispatch builder's segment alignment and the ragged kernel's
    M-tile height are the same contract; drift would misassign tiles."""
    from repro.kernels.grouped_block_sparse.ops import RAGGED_BLOCK_ROWS
    assert moe.RAGGED_BLOCK_ROWS == RAGGED_BLOCK_ROWS


# --------------------------------------------------- capacity regression

@pytest.mark.parametrize("E,top_k,cf,n_tokens", [
    (64, 1, 0.1, 1),      # cf*K*T/E = 0.0016 -> ceil must not hit 0
    (8, 1, 1.0, 1),
    (128, 2, 0.5, 2),
    (4, 2, 1.25, 1),
])
def test_capacity_never_zero_at_tiny_decode_batches(E, top_k, cf, n_tokens):
    spec = MoESpec(n_experts=E, top_k=top_k, d_ff=32, capacity_factor=cf)
    c = moe.capacity(spec, n_tokens)
    assert c >= 1
    # top_k experts per token are distinct, so per-expert demand at a
    # single-token decode tick is 1 — any positive capacity keeps it
    assert c >= top_k * n_tokens / E


def test_single_token_decode_drops_nothing():
    """A (1, 1) decode tick must route its token through all top_k
    experts even under extreme capacity pressure."""
    spec = MoESpec(n_experts=16, top_k=2, d_ff=32, capacity_factor=0.25)
    d = 32
    params = moe.init_moe(jax.random.PRNGKey(0), d, spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, d), jnp.float32)
    y, _ = moe.apply_moe(params, spec, x)
    # a dropped assignment contributes 0; with all kept, the combine is a
    # convex mix of expert outputs and generically nonzero
    assert float(jnp.abs(y).max()) > 0.0


def test_capacity_dropped_assignments_contribute_zero():
    """Over-capacity assignments must combine as exact zeros. The old
    combine remapped drops to a -1 sentinel, and jnp.take's fill mode
    only catches indices >= n — so -1 WRAPPED to the last expert's last
    capacity slot and leaked that token's output into every drop."""
    E, d = 2, 32
    spec = MoESpec(n_experts=E, top_k=1, d_ff=32, capacity_factor=0.5)
    rng = np.random.default_rng(7)
    params = {
        # all-positive tokens x a one-sided router: every token routes
        # to expert 1 (the LAST expert, so its last capacity slot is
        # occupied — exactly the row the -1 wrap used to leak)
        "router": jnp.asarray(
            np.stack([np.zeros(d), np.full(d, 10.0)], axis=1), jnp.float32),
        "up": jnp.asarray(rng.normal(size=(E, d, 32)), jnp.float32),
        "gate": jnp.asarray(rng.normal(size=(E, d, 32)), jnp.float32),
        "down": jnp.asarray(rng.normal(size=(E, 32, d)), jnp.float32),
    }
    x = jnp.asarray(rng.uniform(0.1, 1.0, size=(1, 9, d)), jnp.float32)
    y, _ = moe.apply_moe(params, spec, x)
    # C = max(4, ...) = 4 here: tokens 0-3 keep their slot, 4-8 drop
    kept, dropped = np.asarray(y[0, :4]), np.asarray(y[0, 4:])
    assert float(np.abs(kept).min(axis=-1).max()) > 0.0
    assert float(np.abs(dropped).max()) == 0.0


# --------------------------------------- packed-buffer layout properties

def _routing(rng, E, top_k, cf, G, s):
    """Random router assignments shaped exactly like apply_moe's: top_k
    *distinct* experts per token, capacity keep/pos per (group, expert)."""
    spec = MoESpec(n_experts=E, top_k=top_k, d_ff=32, capacity_factor=cf)
    C = moe.capacity(spec, s)
    ids = np.stack([
        np.stack([rng.permutation(E)[:top_k] for _ in range(s)])
        for _ in range(G)])                                  # (G, s, K)
    flat_ids = jnp.asarray(ids.reshape(G, s * top_k))
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1
    keep = pos < C
    return spec, C, flat_ids, keep, pos


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=8),    # n_experts
       st.integers(min_value=1, max_value=4),    # top_k (clamped to E)
       st.floats(min_value=0.25, max_value=2.0),  # capacity_factor
       st.integers(min_value=1, max_value=4),    # groups (batch)
       st.integers(min_value=1, max_value=9),    # tokens per group
       st.integers(min_value=0, max_value=10**6))  # seed
def test_ragged_dispatch_layout_properties(E, top_k, cf, G, s, seed):
    top_k = min(top_k, E)
    rng = np.random.default_rng(seed)
    spec, C, flat_ids, keep, pos = _routing(rng, E, top_k, cf, G, s)
    A = moe.RAGGED_BLOCK_ROWS
    m_max = moe.ragged_rows_bound(E, G * s * top_k)
    dest, tile_expert, counts_e = moe.build_ragged_dispatch(
        flat_ids, keep, pos, E, m_max)
    dest, tile_expert, counts_e = (np.asarray(dest), np.asarray(tile_expert),
                                   np.asarray(counts_e))
    keep = np.asarray(keep)

    # per-expert counts account for every assignment minus capacity drops
    n_assign = G * s * top_k
    n_dropped = int((~keep).sum())
    assert counts_e.sum() == n_assign - n_dropped
    assert (counts_e <= G * C).all()

    # cumsum offsets: tile-aligned, monotone, and they bound every index
    seg = -(-counts_e // A) * A
    ends = np.cumsum(seg)
    off = ends - seg
    assert (np.diff(ends) >= 0).all() and ends[-1] <= m_max
    kept_dest = dest[keep]
    kept_e = np.asarray(flat_ids)[keep]
    assert (kept_dest < m_max).all()
    assert (kept_dest >= off[kept_e]).all()
    assert (kept_dest < off[kept_e] + counts_e[kept_e]).all()
    # one packed row per kept assignment (the scatter never collides)
    assert len(np.unique(kept_dest)) == keep.sum()
    # dropped assignments land on the dump row
    assert (dest[~keep] == m_max).all()

    # the tile->expert map covers exactly the occupied segments
    n_live_tiles = int((tile_expert >= 0).sum())
    assert n_live_tiles == seg.sum() // A
    for t, e in enumerate(tile_expert):
        if e >= 0:
            assert counts_e[e] > 0
            assert off[e] <= t * A < ends[e]
        else:
            assert t * A >= ends[-1]


# ------------------------------------------------ path equivalence (moe)

def _pruned_moe_layer(E, top_k, cf, seed, d=32, d_ff=32, block=16):
    spec = MoESpec(n_experts=E, top_k=top_k, d_ff=d_ff, capacity_factor=cf)
    params = moe.init_moe(jax.random.PRNGKey(seed), d, spec, jnp.float32)
    rng = np.random.default_rng(seed)
    for nm in ("gate", "up", "down"):
        if nm not in params:
            continue
        w = np.array(params[nm])
        for e in range(E):
            bm = rng.random((w.shape[1] // block, w.shape[2] // block)) < 0.6
            bm[0, 0] = True
            w[e] = np.where(np.repeat(np.repeat(bm, block, 0), block, 1),
                            w[e], 0.0)
        params[nm] = jnp.asarray(w)
    packed = {(0, nm): pack_expert_projection(params[nm], block=block,
                                              group=True, ragged=True)
              for nm in ("gate", "up", "down") if nm in params}
    return spec, params, packed


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=6),    # n_experts
       st.integers(min_value=1, max_value=3),    # top_k (clamped)
       st.floats(min_value=0.5, max_value=1.5),  # capacity_factor
       st.integers(min_value=1, max_value=3),    # batch
       st.integers(min_value=0, max_value=10**6))  # seed
def test_ragged_grouped_loop_dense_identical(E, top_k, cf, B, seed):
    """ragged == grouped == loop bitwise, and all within float-noise of
    the dense einsum, token-for-token, on arbitrary valid MoE shapes."""
    top_k = min(top_k, E)
    spec, params, packed = _pruned_moe_layer(E, top_k, cf, seed)
    bp = {"moe": params}
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, 1, 32),
                         jnp.float32)
    y_dense, _ = moe.apply_moe(params, spec, x)
    y_rag = sparse_apply_moe(bp, spec, x, packed, 0, interpret=True,
                             ragged_moe=True)
    y_grp = sparse_apply_moe(bp, spec, x, packed, 0, interpret=True,
                             group_experts=True, ragged_moe=False)
    y_loop = sparse_apply_moe(bp, spec, x, packed, 0, interpret=True,
                              group_experts=False, ragged_moe=False)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_grp))
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_loop))
    scale = float(jnp.abs(y_dense).max()) + 1e-9
    assert float(jnp.abs(y_rag - y_dense).max() / scale) < 1e-5


def test_ragged_falls_back_to_grouped_on_prefill_sizes():
    """Above RAGGED_TOKENS_MAX the ragged knob defers to the grouped
    capacity-slot launch (and stays output-identical)."""
    from repro.kernels import counters
    spec, params, packed = _pruned_moe_layer(4, 2, 1.25, 3)
    bp = {"moe": params}
    S = RAGGED_TOKENS_MAX + 1
    x = jax.random.normal(jax.random.PRNGKey(9), (1, S, 32), jnp.float32)
    counters.reset()
    y_rag = sparse_apply_moe(bp, spec, x, packed, 0, interpret=True,
                             ragged_moe=True)
    assert "grouped_block_sparse_ragged" not in counters.snapshot()
    y_grp = sparse_apply_moe(bp, spec, x, packed, 0, interpret=True,
                             group_experts=True, ragged_moe=False)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_grp))


# ------------------------------- serving: determinism + no-retrace pins

@pytest.fixture(scope="module")
def ragged_artifact(tmp_path_factory):
    """Mosaic-pruned MoE model packed with ragged_moe=True, saved and
    reloaded (the flag must survive the bundle round-trip)."""
    from repro.core.artifact import PrunedArtifact
    from repro.core.pipeline import MosaicPipeline
    from repro.core.recipe import CalibrationSpec, PruneRecipe
    from repro.models import transformer as T
    from tests.test_moe_sparse import moe_config
    cfg = moe_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.65, category="unstructured",
                         selector="wanda_block", block=16, ragged_moe=True,
                         calibration=CalibrationSpec(4, 2, 16))
    art = MosaicPipeline(recipe).run(params, cfg)
    d = str(tmp_path_factory.mktemp("ragged-moe"))
    art.save(d)
    return art, PrunedArtifact.load(d)


def test_ragged_flag_rides_plans_and_artifact(ragged_artifact):
    from repro.serve.sparse import PackedExpertProjection
    art, loaded = ragged_artifact
    assert art.report["pack"]["ragged_moe"] is True
    for packed in (art.packed, loaded.packed):
        stacks = [p for p in packed.values()
                  if isinstance(p, PackedExpertProjection)]
        assert stacks and all(p.ragged for p in stacks)
    arrays, meta = plans_to_host(art.packed)
    back = plans_from_host(arrays, meta)
    assert all(p.ragged for p in back.values()
               if isinstance(p, PackedExpertProjection))


def test_ragged_serving_token_identical_and_deterministic(ragged_artifact):
    """Sampled tokens through the ragged decode path equal the dense
    engine's per request, survive shuffled arrival order, and the
    occupancy-dependent dispatch never retraces across ticks."""
    from repro.serve.batching import ContinuousEngine
    from repro.serve.config import ServeConfig
    from repro.serve.scheduler import Request

    art, loaded = ragged_artifact
    rng = np.random.default_rng(4)

    def reqs(order):
        rs = [Request(uid=i, prompt=rng_prompts[i],
                      max_new_tokens=6, temperature=0.8, seed=100 + i)
              for i in order]
        return rs

    rng_prompts = {i: rng.integers(0, 256, (n,)).tolist()
                   for i, n in enumerate([5, 9, 7])}
    kw = dict(max_slots=2, max_seq=32, compute_dtype=jnp.float32,
              cache_dtype=jnp.float32)
    dense, _ = ContinuousEngine(art.params, art.cfg,
                                ServeConfig(**kw)).run(reqs([0, 1, 2]))
    by_uid = {f.request.uid: f.tokens for f in dense}

    eng = ContinuousEngine(art.params, art.cfg, ServeConfig(**kw),
                           packed=art.packed)
    for order in ([0, 1, 2], [2, 0, 1], [1, 2, 0]):
        finished, _ = eng.run(reqs(order))
        for f in finished:
            assert f.tokens == by_uid[f.request.uid], \
                f"uid {f.request.uid} diverged at arrival order {order}"
    # occupancy changes per tick; the trace must not
    assert eng._decode_sample._cache_size() == 1

    # and rehydrated from the bundle, same tokens (plans carry ragged)
    loaded_eng = ContinuousEngine.from_artifact(loaded, ServeConfig(**kw))
    finished, _ = loaded_eng.run(reqs([0, 1, 2]))
    for f in finished:
        assert f.tokens == by_uid[f.request.uid]


def test_ragged_static_engine_token_identical(ragged_artifact):
    """The static engine on ragged-packed plans (in-memory AND loaded)
    matches dense token-for-token; decode batches are ragged-eligible."""
    from repro.serve.config import ServeConfig
    from repro.serve.engine import Engine

    art, loaded = ragged_artifact
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                art.cfg.vocab)
    sc = ServeConfig(max_seq=24, compute_dtype=jnp.float32,
                     cache_dtype=jnp.float32)
    dense = np.asarray(Engine(art.params, art.cfg, sc).generate(prompt, 8))
    for eng in (Engine(art.params, art.cfg, sc, packed=art.packed),
                Engine.from_artifact(loaded, sc)):
        np.testing.assert_array_equal(
            dense, np.asarray(eng.generate(prompt, 8)))
