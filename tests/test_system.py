"""End-to-end system behaviour: train -> rank -> prune (all categories)
-> eval perplexity -> LoRA recovery; the Mosaic pipeline on a real (small)
learned model."""
import math
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lora import init_lora, merge_lora
from repro.core.rank_controller import run_ranking_controller
from repro.core.prune_controller import run_pruning_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.serve.engine import Engine
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer
from tests.conftest import small_config


@pytest.fixture(scope="module")
def trained():
    cfg = small_config(vocab=256)
    corpus = SyntheticCorpus(256, seed=0)
    opt = OptConfig(lr=2e-3, warmup_steps=10, total_steps=150)
    tr = Trainer(cfg, opt, corpus.batches(16, 64), ckpt=None,
                 compute_dtype=jnp.float32, prefetch=False)
    rep = tr.run(120)
    assert rep.losses[-1] < rep.losses[0]        # it learned something
    return cfg, tr.state["params"], corpus


def _ppl(params, cfg, corpus, n=4):
    tot = 0.0
    for tokens, labels in corpus.batches(8, 64, start=500, n=n):
        logits, _, _ = T.forward(params, cfg, tokens,
                                 compute_dtype=jnp.float32)
        tot += float(T.cross_entropy(logits, labels, cfg.vocab))
    return math.exp(tot / n)


def test_train_prune_eval_pipeline(trained):
    cfg, params, corpus = trained
    base_ppl = _ppl(params, cfg, corpus)
    assert base_ppl < 150                        # well below vocab=256

    calib = corpus.calibration_batches(8, 4, 64)
    art = run_ranking_controller(params, cfg, calib)

    ppls = {}
    for cat in ("unstructured", "composite", "structured"):
        res = run_pruning_controller(params, cfg, art, 0.3, category=cat,
                                     align_channels=8)
        ppls[cat] = _ppl(res.params, res.cfg, corpus)
        assert np.isfinite(ppls[cat])
    # quality ordering at a moderate target: unstructured <= composite
    # <= structured (paper E3), with slack for small-model noise
    assert ppls["unstructured"] <= ppls["composite"] * 1.5
    assert ppls["composite"] <= ppls["structured"] * 1.5
    assert base_ppl <= ppls["unstructured"] * 1.05


def test_generation_after_pruning(trained):
    cfg, params, corpus = trained
    calib = corpus.calibration_batches(4, 4, 32)
    art = run_ranking_controller(params, cfg, calib)
    res = run_pruning_controller(params, cfg, art, 0.3,
                                 category="composite", align_channels=8)
    eng = Engine(res.params, res.cfg, max_seq=32,
                 compute_dtype=jnp.float32, cache_dtype=jnp.float32)
    prompt = jnp.asarray(corpus.batch(900, 2, 8)[:, :8])
    out = eng.generate(prompt, n_new=8)
    assert out.shape == (2, 16)
    assert bool(jnp.all(out < cfg.vocab))


def test_lora_recovery_improves_pruned_model(trained):
    cfg, params, corpus = trained
    calib = corpus.calibration_batches(4, 4, 32)
    art = run_ranking_controller(params, cfg, calib)
    res = run_pruning_controller(params, cfg, art, 0.5,
                                 category="unstructured")
    pruned_ppl = _ppl(res.params, res.cfg, corpus)

    # train only the adapter for a few steps
    adapters = init_lora(jax.random.PRNGKey(1), res.params, res.cfg, rank=4)

    def loss(ad, tokens, labels):
        merged = merge_lora(res.params, res.cfg, ad, rank=4)
        l, _ = T.loss_fn(merged, res.cfg, tokens, labels,
                         compute_dtype=jnp.float32)
        return l

    from repro.train.optimizer import OptConfig, init_opt, apply_updates
    ocfg = OptConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                     weight_decay=0.0)
    ostate = init_opt(adapters, ocfg)
    gfn = jax.jit(jax.value_and_grad(loss))
    for tokens, labels in corpus.batches(16, 64, start=200, n=40):
        _, g = gfn(adapters, tokens, labels)
        adapters, ostate, _ = apply_updates(adapters, g, ostate, ocfg)
    recovered = merge_lora(res.params, res.cfg, adapters, rank=4)
    rec_ppl = _ppl(recovered, res.cfg, corpus)
    assert rec_ppl < pruned_ppl                  # E4: recovery works
