import jax
import jax.numpy as jnp
import pytest

from repro.models.specs import (AttentionSpec, LayerSpec, MambaSpec, MLPSpec,
                                ModelConfig, MoESpec)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def small_config(scan=False, moe=False, mamba=False, vocab=256) -> ModelConfig:
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
    mlp = MLPSpec(d_ff=128)
    layers = [LayerSpec(attn, mlp)]
    if moe:
        layers.append(LayerSpec(attn, MoESpec(n_experts=4, top_k=2, d_ff=64)))
    if mamba:
        layers.append(LayerSpec(
            MambaSpec(d_inner=128, d_state=16, head_dim=16, chunk=8), None))
    return ModelConfig(name="test", d_model=64, vocab=vocab,
                       vocab_pad_multiple=16, pattern=tuple(layers),
                       n_periods=2, scan_layers=scan, remat=False)


@pytest.fixture(scope="session")
def hybrid_cfg():
    return small_config(moe=True, mamba=True)


@pytest.fixture(scope="session")
def dense_cfg():
    return small_config()
