"""Unstructured / structured / composite pruning + SparseGPT behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.common.tree import param_count, tree_get
from repro.core import structured as S
from repro.core import unstructured as U
from repro.core.composite import prune_composite
from repro.core.planner import plan
from repro.core.rank_controller import run_ranking_controller
from repro.core.prune_controller import Platform, run_pruning_controller, select_category
from repro.core.registry import projections
from repro.core.sparsegpt import sparsegpt_dense
from repro.models import transformer as T
from tests.conftest import small_config


@pytest.fixture(scope="module")
def setup():
    cfg = small_config(moe=True, mamba=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batches = [jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0,
                                  cfg.vocab) for i in range(2)]
    art = run_ranking_controller(params, cfg, batches, want_hessians=True)
    return cfg, params, art, batches


@given(st.floats(0.0, 0.94), st.integers(4, 40), st.integers(4, 40))
@settings(max_examples=30, deadline=None)
def test_mask_exact_sparsity(target, r, c):
    scores = jax.random.uniform(jax.random.PRNGKey(0), (r, c))
    mask = U.mask_from_scores(scores, target)
    assert int(mask.size - mask.sum()) == int(target * r * c)


def test_block_mask_tpu_semistructured():
    """wanda_block: whole tiles pruned -> exactly what the Pallas
    block-sparse kernel skips."""
    scores = jax.random.uniform(jax.random.PRNGKey(5), (64, 64))
    mask = U.block_mask_from_metric(scores, 0.5, block=16)
    m = np.asarray(mask).reshape(4, 16, 4, 16)
    tile_any = m.any(axis=(1, 3))
    tile_all = m.all(axis=(1, 3))
    assert (tile_any == tile_all).all()          # tiles are all-or-nothing
    assert int((~tile_any).sum()) == 8           # exactly 50% of 16 tiles


def test_mask_keeps_highest_scores():
    scores = jnp.arange(20.0).reshape(4, 5)
    mask = U.mask_from_scores(scores, 0.5)
    kept = sorted(np.asarray(scores)[np.asarray(mask)])
    assert kept == list(np.arange(10.0, 20.0))


def test_unstructured_prune_zeroes_and_counts(setup):
    cfg, params, art, _ = setup
    # Eq. 1-2: the *unweighted* per-projection mean equals p
    targets = plan(art.rank, 0.5)
    new_p, masks = U.prune_unstructured(params, cfg, targets,
                                        selector="wanda",
                                        anorms=art.anorms,
                                        per_output=False)
    import numpy as np
    # Eq. 2 per layer: mean of projection fractions == that layer's
    # target; Eq. 1: mean of layer targets == p. (With heterogeneous
    # per-layer projection counts — hybrid archs — the *flat* projection
    # mean differs from p by design; the paper's stack is uniform.)
    by_layer = {}
    for (layer, _), m in masks.items():
        by_layer.setdefault(layer, []).append(1 - float(jnp.mean(m)))
    layer_means = {l: np.mean(v) for l, v in by_layer.items()}
    layer_targets = {}
    for (layer, name), t in targets.items():
        layer_targets.setdefault(layer, []).append(t)
    for l, lm in layer_means.items():
        assert lm == pytest.approx(np.mean(layer_targets[l]), abs=0.02)
    assert np.mean(list(layer_means.values())) == pytest.approx(
        np.mean([np.mean(v) for v in layer_targets.values()]), abs=0.02)
    for proj in projections(cfg):
        w = tree_get(new_p, proj.path)
        m = masks[proj.key]
        assert bool(jnp.all(jnp.where(m, True, w == 0)))
    # param-count-weighted planning: the *overall* sparsity equals p
    targets_w = plan(art.rank, 0.5, weights=art.weights)
    _, masks_w = U.prune_unstructured(params, cfg, targets_w,
                                      selector="wanda",
                                      anorms=art.anorms,
                                      per_output=False)
    assert U.achieved_sparsity(masks_w) == pytest.approx(0.5, abs=0.01)


def test_sparsegpt_identity_hessian_equals_magnitude_blockwise():
    key = jax.random.PRNGKey(3)
    W = jax.random.normal(key, (32, 64))
    H = jnp.eye(32) * 2.0
    Wsp, mask = sparsegpt_dense(W, H, 0.5)
    # with isotropic H there is no error propagation between blocks:
    # selection is pure magnitude within each column block
    assert float(jnp.mean(~mask)) == pytest.approx(0.5, abs=0.02)
    kept = jnp.abs(W)[mask]
    dropped = jnp.abs(W)[~mask]
    assert float(kept.min()) >= float(dropped.max()) - 1e-6


def test_sparsegpt_beats_magnitude_on_reconstruction():
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    X = jax.random.normal(k1, (512, 64)) * jnp.linspace(0.2, 3.0, 64)
    W = jax.random.normal(k2, (64, 32))
    H = X.T @ X
    Wsp, _ = sparsegpt_dense(W, H, 0.6)
    flat = jnp.abs(W).reshape(-1)
    thr = jnp.sort(flat)[int(0.6 * flat.size)]
    Wmag = jnp.where(jnp.abs(W) > thr, W, 0.0)
    err_sp = float(jnp.linalg.norm(X @ Wsp - X @ W))
    err_mag = float(jnp.linalg.norm(X @ Wmag - X @ W))
    assert err_sp < err_mag


def test_structured_shapes_and_equivalence(setup):
    cfg, params, art, batches = setup
    fractions = {(i, u): 0.5 for i in range(cfg.n_layers)
                 for u in ("heads", "ffn", "mamba")}
    new_p, new_cfg = S.prune_structured(params, cfg, fractions)
    assert param_count(new_p) < param_count(params)
    toks = batches[0]
    lo, _, _ = T.forward(new_p, new_cfg, toks, compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(lo).any())
    # spec bookkeeping matches tensor shapes
    for i, spec in enumerate(new_cfg.layers()):
        blk = new_p["blocks"][i]
        if "attn" in blk:
            assert blk["attn"]["q"].shape[1] == spec.mixer.n_q
        if "mlp" in blk:
            assert blk["mlp"]["up"].shape[1] == spec.ffn.d_ff
        if "moe" in blk:
            assert blk["moe"]["up"].shape[2] == spec.ffn.d_ff
        if "mamba" in blk:
            assert blk["mamba"]["out_proj"].shape[0] == spec.mixer.d_inner


def test_structured_zero_fraction_is_identity(setup):
    cfg, params, art, batches = setup
    new_p, new_cfg = S.prune_structured(params, cfg, {})
    toks = batches[0]
    lo0, _, _ = T.forward(params, cfg, toks, compute_dtype=jnp.float32)
    lo1, _, _ = T.forward(new_p, new_cfg, toks, compute_dtype=jnp.float32)
    np.testing.assert_allclose(lo0, lo1, atol=1e-6)


def test_structured_alignment(setup):
    cfg, params, art, _ = setup
    fractions = {(i, u): 0.45 for i in range(cfg.n_layers)
                 for u in ("heads", "ffn", "mamba")}
    new_p, new_cfg = S.prune_structured(params, cfg, fractions,
                                        align_heads=2, align_channels=32)
    for spec in new_cfg.layers():
        from repro.models.specs import AttentionSpec, MambaSpec
        if isinstance(spec.mixer, AttentionSpec):
            assert spec.mixer.n_q % 2 == 0
        if isinstance(spec.mixer, MambaSpec):
            assert spec.mixer.n_heads % 2 == 0
        if spec.ffn is not None:
            assert spec.ffn.d_ff % 32 == 0


def test_composite_between_unstructured_and_structured(setup):
    cfg, params, art, batches = setup
    targets = plan(art.rank, 0.5)
    comp_p, comp_cfg, info = prune_composite(
        params, cfg, targets, anorms=art.anorms, structured_share=0.5)
    assert info["unstructured_sparsity"] == pytest.approx(0.5, abs=0.01)
    assert param_count(comp_p) < param_count(params)
    # composite keeps more params than pure structured at share 1.0
    struct_p, _ = S.prune_structured(
        params, cfg, S.structured_fractions(targets, cfg, 1.0))
    assert param_count(comp_p) > param_count(struct_p)
    lo, _, _ = T.forward(comp_p, comp_cfg, batches[0],
                         compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(lo).any())


def test_expert_pruning_beyond_paper(setup):
    """Whole-expert removal (beyond-paper extension): router and expert
    tensors shrink consistently; forward stays NaN-free."""
    cfg, params, art, batches = setup
    from repro.models.specs import MoESpec
    new_p, new_cfg = S.prune_structured(params, cfg, {}, expert_frac=0.5)
    for i, spec in enumerate(new_cfg.layers()):
        if isinstance(spec.ffn, MoESpec):
            blk = new_p["blocks"][i]["moe"]
            assert spec.ffn.n_experts == 2            # 4 -> 2 at frac 0.5
            assert blk["router"].shape[1] == 2
            assert blk["up"].shape[0] == 2
            assert spec.ffn.n_experts >= spec.ffn.top_k
    lo, _, _ = T.forward(new_p, new_cfg, batches[0],
                         compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(lo).any())


def test_pc_category_selection():
    plat_gpu = Platform("cloud", 100 << 30, has_sparse_accel=True)
    plat_edge = Platform("edge", 1 << 20)
    plat_mid = Platform("mobile", 1 << 30)
    assert select_category(plat_gpu, 10 << 30, 0.5) == "unstructured"
    assert select_category(plat_edge, 10 << 30, 0.5) == "structured"
    assert select_category(plat_mid, 1 << 30, 0.5) == "composite"


@pytest.mark.parametrize("category", ["unstructured", "structured",
                                      "composite"])
def test_pc_end_to_end(setup, category):
    cfg, params, art, batches = setup
    res = run_pruning_controller(params, cfg, art, 0.4, category=category)
    lo, _, _ = T.forward(res.params, res.cfg, batches[0],
                         compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(lo).any())
    assert res.category == category
