"""Property-based tests over the recipe -> artifact pipeline surface:
PruneRecipe JSON round-trips for arbitrary valid field combinations, and
plan_from_recipe invariants (targets bounded, pruned fraction monotone
in p). Runs under real hypothesis when installed, else the seeded
fallback shim in tests/_hypothesis_compat.py."""
import json

from repro.core.planner import plan_from_recipe
from repro.core.recipe import GRANULARITIES, CalibrationSpec, PruneRecipe
from tests._hypothesis_compat import given, settings, st

SELECTOR_NAMES = ("magnitude", "wanda", "wanda_block", "sparsegpt")
CATEGORY_NAMES = (None, "unstructured", "structured", "composite")
STAGE_SUBSETS = (
    ("rank", "plan", "prune", "pack", "report"),
    ("rank", "plan", "prune", "evaluate", "report"),
    ("plan", "prune", "report"),
    ("rank", "plan", "prune"),
)


# ------------------------------------------------------- JSON round-trip

@settings(max_examples=25)
@given(st.floats(min_value=0.0, max_value=0.99),
       st.integers(min_value=0, max_value=len(GRANULARITIES) - 1),
       st.integers(min_value=0, max_value=len(SELECTOR_NAMES) - 1),
       st.integers(min_value=0, max_value=len(CATEGORY_NAMES) - 1),
       st.integers(min_value=0, max_value=len(STAGE_SUBSETS) - 1),
       st.floats(min_value=0.0, max_value=1.0),
       st.floats(min_value=0.0, max_value=0.9),
       st.floats(min_value=0.0, max_value=0.9),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=128),
       st.integers(min_value=1, max_value=256),
       st.integers(min_value=1, max_value=512),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=512),
       st.integers(min_value=0, max_value=10**6))
def test_recipe_json_roundtrip_property(p, gi, si, ci, sti, share, spread,
                                        wspread, heads, chans, block,
                                        n_samples, batch, seq, seed):
    r = PruneRecipe(
        arch="llama3-8b", p=p,
        category=CATEGORY_NAMES[ci],
        granularity=GRANULARITIES[gi],
        selector=SELECTOR_NAMES[si],
        spread=spread, within_spread=wspread,
        structured_share=share,
        align_heads=heads, align_channels=chans,
        per_output=bool(seed % 2), block=block,
        calibration=CalibrationSpec(n_samples=n_samples, batch_size=batch,
                                    seq_len=seq, seed=seed),
        stages=STAGE_SUBSETS[sti])
    assert PruneRecipe.from_json(r.to_json()) == r
    # and through real JSON serialisation of the dict form (tuples->lists)
    assert PruneRecipe.from_dict(json.loads(json.dumps(r.to_dict()))) == r


# --------------------------------------------------------- plan invariants

def _rank_and_weights(values):
    """Synthetic profile: two projections per layer from drawn values."""
    rank = {}
    weights = {}
    for i, v in enumerate(values):
        key = (i // 2, ("q", "up")[i % 2])
        rank[key] = float(v)
        weights[key] = 64 + 13 * i
    return rank, weights


def _pruned_fraction(targets, weights):
    tot = sum(weights.values())
    return sum(t * weights[k] for k, t in targets.items()) / tot


@settings(max_examples=20)
@given(st.lists(st.floats(min_value=0.01, max_value=10.0),
                min_size=4, max_size=12),
       st.floats(min_value=0.0, max_value=0.9),
       st.floats(min_value=0.0, max_value=0.9),
       st.integers(min_value=0, max_value=len(GRANULARITIES) - 1),
       st.floats(min_value=0.0, max_value=0.5),
       st.floats(min_value=0.0, max_value=0.5))
def test_plan_targets_bounded_and_monotone(values, p_a, p_b, gi, spread,
                                           wspread):
    rank, weights = _rank_and_weights(values)
    lo, hi = sorted((p_a, p_b))
    recipe = PruneRecipe(arch="t", p=lo, granularity=GRANULARITIES[gi],
                         spread=spread, within_spread=wspread)
    fracs = []
    for p in (lo, hi):
        targets = plan_from_recipe(rank, recipe.replace(p=p),
                                   weights=weights)
        assert set(targets) == set(rank)
        for t in targets.values():
            assert 0.0 <= t <= 1.0, targets
        fracs.append(_pruned_fraction(targets, weights))
    # total pruned-parameter fraction is monotone non-decreasing in p
    assert fracs[1] >= fracs[0] - 1e-6, (lo, hi, fracs)


@settings(max_examples=15)
@given(st.lists(st.floats(min_value=0.01, max_value=10.0),
                min_size=4, max_size=10),
       st.floats(min_value=0.05, max_value=0.85),
       st.floats(min_value=0.0, max_value=0.5))
def test_plan_weighted_mean_hits_p(values, p, spread):
    """Eq. 1/2: the param-weighted mean target equals p (all granularities
    stay inside the clipping regime for these ranges)."""
    rank, weights = _rank_and_weights(values)
    for g in GRANULARITIES:
        recipe = PruneRecipe(arch="t", p=p, granularity=g, spread=spread)
        targets = plan_from_recipe(rank, recipe, weights=weights)
        frac = _pruned_fraction(targets, weights)
        assert abs(frac - p) < 5e-2, (g, p, frac)


def test_recipe_rejects_out_of_range_combinations():
    for bad in (dict(p=1.0), dict(p=-0.1), dict(structured_share=2.0),
                dict(granularity="row"), dict(block=0)):
        try:
            PruneRecipe(arch="a", **{"p": 0.5, **bad})
        except ValueError:
            continue
        raise AssertionError(f"accepted invalid recipe: {bad}")
