"""Distributed runtime tests. Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process (and all other tests) keep seeing 1 device."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str) -> dict:
    """Run `body` under 8 fake devices; body must print a JSON dict."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import json\n" + textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pipeline_parallel_matches_sequential():
    res = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_forward
        mesh = Mesh(np.array(jax.devices()[:4]), ("stage",))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (4, 16, 16)) * 0.3
        def stage_fn(p, x): return jnp.tanh(x @ p["w"])
        x = jax.random.normal(key, (6, 8, 16))
        out = pipeline_forward(stage_fn, {"w": Ws}, x, mesh, axis="stage")
        def seq(x1):
            for i in range(4): x1 = stage_fn({"w": Ws[i]}, x1)
            return x1
        ref = jax.vmap(seq)(x)
        print(json.dumps({"err": float(jnp.abs(out - ref).max())}))
    """)
    assert res["err"] < 1e-6


def test_compressed_psum_accuracy_and_error_feedback():
    res = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        def f(gl):
            red, e = compressed_psum({"g": gl}, "pod")
            return red["g"], e["g"]
        from repro.common.compat import shard_map
        fm = shard_map(f, mesh=mesh, in_specs=P("pod"),
                           out_specs=(P(), P("pod")))
        red, e = fm(g)
        print(json.dumps({
            "err": float(jnp.abs(red[0] - g.mean(0)).max()),
            "ef_nonzero": float(jnp.abs(e).max()),
        }))
    """)
    assert res["err"] < 0.02          # int8 quantisation error bound
    assert res["ef_nonzero"] > 0      # residual captured for next step


def test_sharded_forward_and_decode():
    res = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed import sharding as SH
        from repro.models import transformer as T
        from repro.models.specs import *
        from repro.serve.engine import make_serve_step
        mesh = Mesh(np.array(jax.devices()).reshape(2,2,2),
                    ("pod","data","model"))
        attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
        cfg = ModelConfig(name="t", d_model=64, vocab=256,
            vocab_pad_multiple=16,
            pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),
                     LayerSpec(MambaSpec(d_inner=128, d_state=16,
                                         head_dim=16, chunk=8),
                               MoESpec(n_experts=4, top_k=2, d_ff=64))),
            n_periods=2, scan_layers=True, remat=False)
        shd = SH.param_shardings(mesh, cfg)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(jax.device_put, params, shd)
        toks = jnp.zeros((8, 16), jnp.int32)
        f = jax.jit(lambda p, t: T.forward(p, cfg, t)[0],
                    in_shardings=(shd, SH.input_sharding(mesh, 8)))
        lo = f(params, toks)
        cache = jax.tree.map(jax.device_put, T.init_cache(cfg, 8, 32),
                             SH.cache_shardings(mesh, cfg, 8))
        ss = jax.jit(make_serve_step(cfg))
        lo1, _ = ss(params, cache, toks[:, :1], jnp.int32(0))
        # sharded-vs-single-device numerical check (fp32 compute so the
        # comparison isn't dominated by bf16 reduction-order noise)
        f32 = jax.jit(lambda p, t: T.forward(
                          p, cfg, t, compute_dtype=jnp.float32)[0],
                      in_shardings=(shd, SH.input_sharding(mesh, 8)))
        lo32 = f32(params, toks)
        params_h = jax.device_get(params)
        lo_ref = T.forward(params_h, cfg, jax.device_get(toks),
                           compute_dtype=jnp.float32)[0]
        err = float(jnp.abs(lo32 - lo_ref).max())
        print(json.dumps({"fwd": list(lo.shape), "dec": list(lo1.shape),
                          "err": err}))
    """)
    assert res["fwd"] == [8, 16, 256]
    assert res["dec"] == [8, 256]
    assert res["err"] < 1e-4          # fp32 reduction-order tolerance


def test_elastic_mesh_and_resharding_restore():
    res = run_subprocess("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint.manager import CheckpointManager
        from repro.distributed import sharding as SH
        from repro.distributed.elastic import (choose_mesh_shape,
                                               make_elastic_mesh)
        from repro.models import transformer as T
        from repro.models.specs import *
        attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
        cfg = ModelConfig(name="t", d_model=64, vocab=256,
                          vocab_pad_multiple=16,
                          pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),),
                          n_periods=2, scan_layers=False, remat=False)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, params, blocking=True)
            # restore onto an 8-device mesh (as if the fleet grew)
            mesh = make_elastic_mesh(8, target_tp=2)
            shd = SH.param_shardings(mesh, cfg)
            like = jax.tree.map(jnp.zeros_like, params)
            restored = mgr.restore(like, shardings=shd)
            ok = all(bool(jnp.allclose(a, b)) for a, b in
                     zip(jax.tree.leaves(params),
                         jax.tree.leaves(jax.device_get(restored))))
        print(json.dumps({"ok": ok,
                          "shape512": choose_mesh_shape(512, 16, True),
                          "shape6": choose_mesh_shape(6, 16)}))
    """)
    assert res["ok"]
    assert res["shape512"] == [2, 16, 16]
    assert res["shape6"] == [1, 6]


def test_dryrun_smoke_cell():
    """One real dry-run cell on the full 512-device production mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-1.3b", "--shape", "decode_32k", "--no-cost-periods"],
        env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ALL DRY-RUN CELLS COMPILED" in out.stdout
