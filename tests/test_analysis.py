"""Unit tests for the dry-run analysis layer (HLO collective parsing,
roofline terms, extrapolation)."""
import pytest

from repro.launch import analysis as AN


HLO_SAMPLE = """
HloModule jit_step
  %ag = bf16[128,256] all-gather(%p0), dimensions={0}
  %ar.1 = f32[1024] all-reduce(%x), to_apply=%add
  %rs = bf16[64,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[32,32] all-to-all(%z), dimensions={1}
  %cp = s32[16] collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (bf16[8,8], bf16[8,8]) all-gather-start(%q), dimensions={0}
  %agd = bf16[8,8] all-gather-done(%ags)
  %dot = f32[128,128] dot(%a, %b), lhs_contracting_dims={1}
"""


def test_collective_bytes_parses_all_kinds():
    out = AN.collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 128 * 256 * 2 + 2 * 8 * 8 * 2  # incl. -start tuple
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 64 * 64 * 2
    assert out["all-to-all"] == 32 * 32 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == sum(out[k] for k in AN.COLLECTIVES)


def test_done_ops_not_double_counted():
    out = AN.collective_bytes(HLO_SAMPLE)
    # the -done op carries the same bytes; only -start is counted
    assert out["counts"]["all-gather"] == 2


def test_roofline_terms_and_bottleneck():
    t = AN.roofline_terms(flops=197e12, bytes_accessed=819e9,
                          coll_bytes=0.0, n_chips=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    t2 = AN.roofline_terms(flops=1.0, bytes_accessed=1.0,
                           coll_bytes=50e9, n_chips=256)
    assert t2["bottleneck"] == "collective_s"


def test_extrapolate_affine():
    c1 = {"flops": 10.0, "nested": {"x": 1.0}}
    c2 = {"flops": 14.0, "nested": {"x": 1.5}}
    out = AN.extrapolate(c1, c2, n_periods=5)
    assert out["flops"] == pytest.approx(10 + 4 * 4)
    assert out["nested"]["x"] == pytest.approx(1 + 4 * 0.5)
