"""Gateway front door: wire-schema validation, EngineBridge streaming
token-identity against driving the engine directly (contiguous and
paged/prefix-shared, greedy and sampled), the HTTP surface (ndjson
streaming, /metrics, /healthz, 400s), structured reject reasons on the
response path, and artifact-driven placement sizing."""
import asyncio
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from conftest import small_config
from repro.models import transformer as T
from repro.models.specs import config_to_dict
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.gateway import (Gateway, GenerateRequest, ProtocolError,
                                 parse_request, plan_placement)
from repro.serve.gateway import protocol as P
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def served():
    cfg = small_config()
    return T.init_model(jax.random.PRNGKey(0), cfg), cfg


def _serve_cfg(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 32)
    return ServeConfig(compute_dtype=jnp.float32,
                       cache_dtype=jnp.float32, **kw)


# -------------------------------------------------------------- protocol

def test_parse_request_happy_path():
    greq = parse_request({"tokens": [1, 2, 3], "max_new_tokens": 4,
                          "temperature": 0.5, "seed": 7, "priority": 2,
                          "prefix_id": "sys", "deadline_ms": 100,
                          "eos_id": 0, "stream": False}, vocab=256)
    assert greq.tokens == (1, 2, 3) and not greq.stream
    req = P.to_engine_request(greq, uid=9, vocab=256)
    assert isinstance(req, Request)
    assert (req.uid, req.priority, req.deadline_ms) == (9, 2, 100)


def test_parse_request_prompt_encodes_bytes():
    greq = parse_request({"prompt": "hi"}, vocab=256)
    req = P.to_engine_request(greq, uid=0, vocab=256)
    assert req.prompt == [ord("h"), ord("i")]


@pytest.mark.parametrize("body", [
    [],                                          # not an object
    {},                                          # neither prompt nor tokens
    {"prompt": "x", "tokens": [1]},              # both
    {"tokens": []},                              # empty
    {"tokens": [1, "a"]},                        # non-int
    {"tokens": [999999]},                        # out of vocab
    {"tokens": [1], "max_new_tokens": 0},
    {"tokens": [1], "deadline_ms": -5},
    {"tokens": [1], "seed": "x"},
    {"tokens": [1], "bogus": 1},                 # unknown field
])
def test_parse_request_rejects(body):
    with pytest.raises(ProtocolError):
        parse_request(body, vocab=256)


def test_request_fields_match_dataclass():
    assert set(P.REQUEST_FIELDS) == {
        f.name for f in dataclasses.fields(GenerateRequest)}


# ------------------------------------------------------- token identity

def _http_generate(port, body: dict):
    """One raw POST /generate; returns the parsed ndjson event list
    (or the single JSON object for non-streaming responses)."""
    async def go():
        r, w = await asyncio.open_connection("127.0.0.1", port)
        payload = json.dumps(body).encode()
        w.write(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
                b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
        await w.drain()
        data = await r.read()
        w.close()
        await w.wait_closed()
        return data
    data = asyncio.run(go())
    head, _, body_bytes = data.partition(b"\r\n\r\n")
    events = [json.loads(line) for line in body_bytes.splitlines()
              if line.strip()]
    status = int(head.split(b" ", 2)[1])
    return status, events


def _roundtrip(params, cfg, serve, wire_reqs, engine_reqs,
               temperature=0.0, seed=0):
    """Serve ``wire_reqs`` through a real HTTP gateway and return
    per-uid token lists, plus the direct-engine outputs for
    ``engine_reqs`` on an identically-configured engine."""
    direct_eng = ContinuousEngine(params, cfg, serve)
    fin, _ = direct_eng.run(engine_reqs, temperature=temperature,
                            seed=seed)
    direct = {f.request.uid: f.tokens for f in fin}

    async def go():
        gw = await Gateway(ContinuousEngine(params, cfg, serve),
                           temperature=temperature, seed=seed).start()

        async def one(body):
            r, w = await asyncio.open_connection("127.0.0.1", gw.port)
            payload = json.dumps(body).encode()
            w.write(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
                    b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
            await w.drain()
            data = await r.read()
            w.close()
            return [json.loads(line) for line in
                    data.partition(b"\r\n\r\n")[2].splitlines()
                    if line.strip()]
        results = await asyncio.gather(*[one(b) for b in wire_reqs])
        await gw.close()
        return results

    streamed = {}
    for events in asyncio.run(go()):
        done = [e for e in events if e["event"] == "done"]
        assert done, f"no terminal event in {events}"
        toks = [e["token"] for e in events if e["event"] == "token"]
        assert toks == done[0]["tokens"], "stream disagrees with done"
        streamed[done[0]["uid"]] = done[0]["tokens"]
    return direct, streamed


def test_gateway_token_identity_contiguous_sampled(served):
    params, cfg = served
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5]]
    wire = [{"tokens": p, "max_new_tokens": 6, "temperature": 0.8,
             "seed": 40 + i} for i, p in enumerate(prompts)]
    engine = [Request(uid=i, prompt=p, max_new_tokens=6, temperature=0.8,
                      seed=40 + i) for i, p in enumerate(prompts)]
    direct, streamed = _roundtrip(params, cfg, _serve_cfg(), wire, engine)
    assert streamed == direct


def test_gateway_token_identity_paged_shared_prefix(served):
    params, cfg = served
    serve = _serve_cfg(max_seq=64, block_size=8, prefill_chunk=8)
    prefix = list(range(1, 17))
    tails = [[20 + i] for i in range(3)]
    wire = [{"tokens": prefix + t, "max_new_tokens": 5,
             "prefix_id": "sys"} for t in tails]
    engine = [Request(uid=i, prompt=prefix + t, max_new_tokens=5,
                      prefix_id="sys") for i, t in enumerate(tails)]
    direct, streamed = _roundtrip(params, cfg, serve, wire, engine)
    assert streamed == direct


# --------------------------------------------------------- http surface

def test_gateway_http_endpoints_and_rejects(served):
    params, cfg = served

    async def go():
        eng = ContinuousEngine(params, cfg, _serve_cfg(scheduler="slo"))
        gw = await Gateway(eng).start()

        async def raw(request: bytes):
            r, w = await asyncio.open_connection("127.0.0.1", gw.port)
            w.write(request)
            await w.drain()
            data = await r.read()
            w.close()
            return data

        health = await raw(b"GET /healthz HTTP/1.1\r\n\r\n")
        assert health.startswith(b"HTTP/1.1 200")
        assert json.loads(health.partition(b"\r\n\r\n")[2]) == {
            "status": "ok"}

        missing = await raw(b"GET /nope HTTP/1.1\r\n\r\n")
        assert missing.startswith(b"HTTP/1.1 404")

        bad = json.dumps({"tokens": []}).encode()
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(bad) + bad)
        assert resp.startswith(b"HTTP/1.1 400")
        assert json.loads(resp.partition(b"\r\n\r\n")[2])["event"] == \
            "error"

        # non-streaming: single JSON terminal event
        body = json.dumps({"tokens": [1, 2], "max_new_tokens": 3,
                           "stream": False}).encode()
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(body) + body)
        done = json.loads(resp.partition(b"\r\n\r\n")[2])
        assert done["event"] == "done" and len(done["tokens"]) == 3
        assert set(done["metrics"]) == {"queue_ms", "prefill_ms",
                                        "decode_ms", "total_ms"}

        # oversize prompt -> structured rejected event on the wire
        body = json.dumps({"tokens": [1] * 40, "max_new_tokens": 2,
                           "stream": False}).encode()
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(body) + body)
        rej = json.loads(resp.partition(b"\r\n\r\n")[2])
        assert rej == {"event": "rejected", "uid": rej["uid"],
                       "reason": "prompt_too_long"}

        metrics = await raw(b"GET /metrics HTTP/1.1\r\n\r\n")
        m = json.loads(metrics.partition(b"\r\n\r\n")[2])
        assert m["metrics"]["counters"]["requests.finished"] == 1.0
        assert m["metrics"]["counters"][
            "requests.rejected.prompt_too_long"] == 1.0
        assert "request.total_ms" in m["metrics"]["series"]
        assert m["stats"]["reject_reasons"] == {"prompt_too_long": 1}

        fin, stats = await gw.close()
        return fin, stats

    fin, stats = asyncio.run(go())
    assert len(fin) == 1 and stats.rejected == 1
    assert stats.reject_reasons == {"prompt_too_long": 1}


# ------------------------------------------------------------ placement

def test_plan_placement_from_report(tmp_path, served):
    _, cfg = served
    (tmp_path / "report.json").write_text(json.dumps(
        {"bytes_after": 1 << 20, "params_before": 1000,
         "params_after": 600}))
    (tmp_path / "config.json").write_text(
        json.dumps(config_to_dict(cfg)))
    # cfg: 2 periods x 1 attention layer, n_kv=2, head_dim=16, f32
    place = plan_placement(tmp_path, 8 << 20, max_seq=64, block_size=8,
                           cache_dtype=jnp.float32, headroom=0.0)
    assert place.kv_token_bytes == 2 * 2 * 2 * 16 * 4
    assert place.weights_bytes == 1 << 20
    assert place.density == pytest.approx(0.6)
    expected_tokens = ((8 << 20) - (1 << 20)) // place.kv_token_bytes
    assert place.kv_tokens == expected_tokens
    assert place.serve.n_blocks == expected_tokens // 8
    assert place.serve.paged and place.serve.max_seq == 64

    contig = plan_placement(tmp_path, 8 << 20, max_seq=64,
                            cache_dtype=jnp.float32, max_slots=4)
    assert contig.serve.max_slots == 4 and contig.serve.block_size is None

    with pytest.raises(ValueError):        # weights alone bust the budget
        plan_placement(tmp_path, 1 << 20, max_seq=64)
