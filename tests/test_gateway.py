"""Gateway front door: wire-schema validation, EngineBridge streaming
token-identity against driving the engine directly (contiguous and
paged/prefix-shared, greedy and sampled), the HTTP surface (ndjson
streaming, /metrics, /healthz, 400s), structured reject reasons on the
response path, and artifact-driven placement sizing."""
import asyncio
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from conftest import small_config
from repro.models import transformer as T
from repro.models.specs import config_to_dict
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.gateway import (Gateway, GenerateRequest, ProtocolError,
                                 parse_request, plan_placement)
from repro.serve.gateway import protocol as P
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def served():
    cfg = small_config()
    return T.init_model(jax.random.PRNGKey(0), cfg), cfg


def _serve_cfg(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 32)
    return ServeConfig(compute_dtype=jnp.float32,
                       cache_dtype=jnp.float32, **kw)


# -------------------------------------------------------------- protocol

def test_parse_request_happy_path():
    greq = parse_request({"tokens": [1, 2, 3], "max_new_tokens": 4,
                          "temperature": 0.5, "seed": 7, "priority": 2,
                          "prefix_id": "sys", "deadline_ms": 100,
                          "eos_id": 0, "stream": False}, vocab=256)
    assert greq.tokens == (1, 2, 3) and not greq.stream
    req = P.to_engine_request(greq, uid=9, vocab=256)
    assert isinstance(req, Request)
    assert (req.uid, req.priority, req.deadline_ms) == (9, 2, 100)


def test_parse_request_prompt_encodes_bytes():
    greq = parse_request({"prompt": "hi"}, vocab=256)
    req = P.to_engine_request(greq, uid=0, vocab=256)
    assert req.prompt == [ord("h"), ord("i")]


@pytest.mark.parametrize("body", [
    [],                                          # not an object
    {},                                          # neither prompt nor tokens
    {"prompt": "x", "tokens": [1]},              # both
    {"tokens": []},                              # empty
    {"tokens": [1, "a"]},                        # non-int
    {"tokens": [999999]},                        # out of vocab
    {"tokens": [1], "max_new_tokens": 0},
    {"tokens": [1], "deadline_ms": -5},
    {"tokens": [1], "seed": "x"},
    {"tokens": [1], "bogus": 1},                 # unknown field
])
def test_parse_request_rejects(body):
    with pytest.raises(ProtocolError):
        parse_request(body, vocab=256)


def test_request_fields_match_dataclass():
    assert set(P.REQUEST_FIELDS) == {
        f.name for f in dataclasses.fields(GenerateRequest)}


# ------------------------------------------------------- token identity

def _http_generate(port, body: dict):
    """One raw POST /generate; returns the parsed ndjson event list
    (or the single JSON object for non-streaming responses)."""
    async def go():
        r, w = await asyncio.open_connection("127.0.0.1", port)
        payload = json.dumps(body).encode()
        w.write(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
                b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
        await w.drain()
        data = await r.read()
        w.close()
        await w.wait_closed()
        return data
    data = asyncio.run(go())
    head, _, body_bytes = data.partition(b"\r\n\r\n")
    events = [json.loads(line) for line in body_bytes.splitlines()
              if line.strip()]
    status = int(head.split(b" ", 2)[1])
    return status, events


def _roundtrip(params, cfg, serve, wire_reqs, engine_reqs,
               temperature=0.0, seed=0):
    """Serve ``wire_reqs`` through a real HTTP gateway and return
    per-uid token lists, plus the direct-engine outputs for
    ``engine_reqs`` on an identically-configured engine."""
    direct_eng = ContinuousEngine(params, cfg, serve)
    fin, _ = direct_eng.run(engine_reqs, temperature=temperature,
                            seed=seed)
    direct = {f.request.uid: f.tokens for f in fin}

    async def go():
        gw = await Gateway(ContinuousEngine(params, cfg, serve),
                           temperature=temperature, seed=seed).start()

        async def one(body):
            r, w = await asyncio.open_connection("127.0.0.1", gw.port)
            payload = json.dumps(body).encode()
            w.write(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
                    b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
            await w.drain()
            data = await r.read()
            w.close()
            return [json.loads(line) for line in
                    data.partition(b"\r\n\r\n")[2].splitlines()
                    if line.strip()]
        results = await asyncio.gather(*[one(b) for b in wire_reqs])
        await gw.close()
        return results

    streamed = {}
    for events in asyncio.run(go()):
        done = [e for e in events if e["event"] == "done"]
        assert done, f"no terminal event in {events}"
        toks = [e["token"] for e in events if e["event"] == "token"]
        assert toks == done[0]["tokens"], "stream disagrees with done"
        streamed[done[0]["uid"]] = done[0]["tokens"]
    return direct, streamed


def test_gateway_token_identity_contiguous_sampled(served):
    params, cfg = served
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5]]
    wire = [{"tokens": p, "max_new_tokens": 6, "temperature": 0.8,
             "seed": 40 + i} for i, p in enumerate(prompts)]
    engine = [Request(uid=i, prompt=p, max_new_tokens=6, temperature=0.8,
                      seed=40 + i) for i, p in enumerate(prompts)]
    direct, streamed = _roundtrip(params, cfg, _serve_cfg(), wire, engine)
    assert streamed == direct


def test_gateway_token_identity_paged_shared_prefix(served):
    params, cfg = served
    serve = _serve_cfg(max_seq=64, block_size=8, prefill_chunk=8)
    prefix = list(range(1, 17))
    tails = [[20 + i] for i in range(3)]
    wire = [{"tokens": prefix + t, "max_new_tokens": 5,
             "prefix_id": "sys"} for t in tails]
    engine = [Request(uid=i, prompt=prefix + t, max_new_tokens=5,
                      prefix_id="sys") for i, t in enumerate(tails)]
    direct, streamed = _roundtrip(params, cfg, serve, wire, engine)
    assert streamed == direct


# --------------------------------------------------------- http surface

def test_gateway_http_endpoints_and_rejects(served):
    params, cfg = served

    async def go():
        eng = ContinuousEngine(params, cfg, _serve_cfg(scheduler="slo"))
        gw = await Gateway(eng).start()

        async def raw(request: bytes):
            r, w = await asyncio.open_connection("127.0.0.1", gw.port)
            w.write(request)
            await w.drain()
            data = await r.read()
            w.close()
            return data

        health = await raw(b"GET /healthz HTTP/1.1\r\n\r\n")
        assert health.startswith(b"HTTP/1.1 200")
        assert json.loads(health.partition(b"\r\n\r\n")[2]) == {
            "status": "ok"}

        missing = await raw(b"GET /nope HTTP/1.1\r\n\r\n")
        assert missing.startswith(b"HTTP/1.1 404")

        bad = json.dumps({"tokens": []}).encode()
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(bad) + bad)
        assert resp.startswith(b"HTTP/1.1 400")
        assert json.loads(resp.partition(b"\r\n\r\n")[2])["event"] == \
            "error"

        # non-streaming: single JSON terminal event
        body = json.dumps({"tokens": [1, 2], "max_new_tokens": 3,
                           "stream": False}).encode()
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(body) + body)
        done = json.loads(resp.partition(b"\r\n\r\n")[2])
        assert done["event"] == "done" and len(done["tokens"]) == 3
        assert set(done["metrics"]) == {"queue_ms", "prefill_ms",
                                        "decode_ms", "total_ms"}

        # oversize prompt -> structured rejected event on the wire
        body = json.dumps({"tokens": [1] * 40, "max_new_tokens": 2,
                           "stream": False}).encode()
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(body) + body)
        rej = json.loads(resp.partition(b"\r\n\r\n")[2])
        assert rej == {"event": "rejected", "uid": rej["uid"],
                       "reason": "prompt_too_long"}

        metrics = await raw(b"GET /metrics HTTP/1.1\r\n\r\n")
        m = json.loads(metrics.partition(b"\r\n\r\n")[2])
        assert m["metrics"]["counters"]["requests.finished"] == 1.0
        assert m["metrics"]["counters"][
            "requests.rejected.prompt_too_long"] == 1.0
        assert "request.total_ms" in m["metrics"]["series"]
        assert m["stats"]["reject_reasons"] == {"prompt_too_long": 1}

        fin, stats = await gw.close()
        return fin, stats

    fin, stats = asyncio.run(go())
    assert len(fin) == 1 and stats.rejected == 1
    assert stats.reject_reasons == {"prompt_too_long": 1}


# ----------------------------------------------------- crash propagation

def _crash_after(eng, n_bursts: int, exc: Exception):
    """Make the engine's decode burst raise on its ``n_bursts``-th call,
    simulating a device failure mid-serving."""
    calls = {"n": 0}
    orig = eng._decode_burst

    def dying(*a, **kw):
        calls["n"] += 1
        if calls["n"] >= n_bursts:
            raise exc
        return orig(*a, **kw)

    eng._decode_burst = dying


def test_gateway_engine_crash_streams_error_and_degrades(served):
    """Engine thread death mid-stream must surface as a terminal wire
    ``error`` event carrying the request's uid (not a silent hang),
    flip /healthz to 503, refuse new submissions with 503, and re-raise
    from ``close()`` — the failure is never swallowed."""
    params, cfg = served
    eng = ContinuousEngine(params, cfg, _serve_cfg())
    _crash_after(eng, 2, RuntimeError("injected device failure"))

    async def go():
        gw = await Gateway(eng).start()

        async def raw(request: bytes):
            r, w = await asyncio.open_connection("127.0.0.1", gw.port)
            w.write(request)
            await w.drain()
            data = await r.read()
            w.close()
            return data

        # long request: first burst streams tokens, second burst dies
        payload = json.dumps({"tokens": [1, 2, 3],
                              "max_new_tokens": 30}).encode()
        data = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(payload)
                         + payload)
        events = [json.loads(line) for line in
                  data.partition(b"\r\n\r\n")[2].splitlines()
                  if line.strip()]
        assert events, "stream hung instead of erroring"
        assert [e["event"] for e in events[:-1]].count("token") == \
            len(events) - 1
        assert len(events) > 1, "no tokens streamed before the crash"
        last = events[-1]
        assert last["event"] == "error" and last["uid"] == events[0]["uid"]
        assert "injected device failure" in last["error"]

        # the gateway is now degraded, not pretending to be healthy
        health = await raw(b"GET /healthz HTTP/1.1\r\n\r\n")
        assert health.startswith(b"HTTP/1.1 503")
        resp = await raw(b"POST /generate HTTP/1.1\r\n"
                         b"Content-Length: %d\r\n\r\n" % len(payload)
                         + payload)
        assert resp.startswith(b"HTTP/1.1 503")
        assert json.loads(resp.partition(b"\r\n\r\n")[2])["event"] == \
            "error"

        with pytest.raises(RuntimeError, match="injected device failure"):
            await gw.close()

    asyncio.run(go())


def test_gateway_engine_crash_buffered_returns_503(served):
    """The buffered (``stream: false``) path used to return the
    terminal event with HTTP 200 even when it was an engine-death
    ``error`` — a crash must not masquerade as a completion."""
    params, cfg = served
    eng = ContinuousEngine(params, cfg, _serve_cfg())
    _crash_after(eng, 1, RuntimeError("injected device failure"))

    async def go():
        gw = await Gateway(eng).start()
        payload = json.dumps({"tokens": [4, 5, 6], "max_new_tokens": 8,
                              "stream": False}).encode()
        r, w = await asyncio.open_connection("127.0.0.1", gw.port)
        w.write(b"POST /generate HTTP/1.1\r\n"
                b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
        await w.drain()
        data = await r.read()
        w.close()
        assert data.startswith(b"HTTP/1.1 503")
        ev = json.loads(data.partition(b"\r\n\r\n")[2])
        assert ev["event"] == "error" and "injected" in ev["error"]
        assert ev["uid"] == 0
        with pytest.raises(RuntimeError, match="injected device failure"):
            await gw.close()

    asyncio.run(go())


# ------------------------------------------------------------ placement

def test_plan_placement_from_report(tmp_path, served):
    _, cfg = served
    (tmp_path / "report.json").write_text(json.dumps(
        {"bytes_after": 1 << 20, "params_before": 1000,
         "params_after": 600}))
    (tmp_path / "config.json").write_text(
        json.dumps(config_to_dict(cfg)))
    # cfg: 2 periods x 1 attention layer, n_kv=2, head_dim=16, f32
    place = plan_placement(tmp_path, 8 << 20, max_seq=64, block_size=8,
                           cache_dtype=jnp.float32, headroom=0.0)
    assert place.kv_token_bytes == 2 * 2 * 2 * 16 * 4
    assert place.weights_bytes == 1 << 20
    assert place.density == pytest.approx(0.6)
    budget_tokens = ((8 << 20) - (1 << 20)) // place.kv_token_bytes
    # the arena allocates n_blocks + 1 (scratch) blocks, so one block of
    # the budget goes to scratch and the usable capacity excludes it
    expected_blocks = budget_tokens // 8 - 1
    assert place.serve.n_blocks == expected_blocks
    assert place.kv_tokens == expected_blocks * 8
    # plan must fit the budget *including* the scratch block
    arena_bytes = (expected_blocks + 1) * 8 * place.kv_token_bytes
    assert place.weights_bytes + arena_bytes <= 8 << 20
    assert place.serve.paged and place.serve.max_seq == 64
    # slot cap rounds down to full max_seq sequences
    assert place.serve.max_slots <= expected_blocks // (64 // 8)

    contig = plan_placement(tmp_path, 8 << 20, max_seq=64,
                            cache_dtype=jnp.float32, max_slots=4)
    assert contig.serve.max_slots == 4 and contig.serve.block_size is None

    with pytest.raises(ValueError):        # weights alone bust the budget
        plan_placement(tmp_path, 1 << 20, max_seq=64)


def test_plan_placement_rejects_bad_block_size(tmp_path, served):
    """block_size > max_seq used to crash with ZeroDivisionError at
    ``n_blocks // (max_seq // block_size)``; both it and a non-dividing
    block_size must fail with a clear ValueError up front."""
    _, cfg = served
    (tmp_path / "report.json").write_text(json.dumps(
        {"bytes_after": 1 << 20, "params_before": 1000,
         "params_after": 600}))
    (tmp_path / "config.json").write_text(json.dumps(config_to_dict(cfg)))
    with pytest.raises(ValueError, match="block_size"):
        plan_placement(tmp_path, 8 << 20, max_seq=64, block_size=128,
                       cache_dtype=jnp.float32)
    with pytest.raises(ValueError, match="block_size"):
        plan_placement(tmp_path, 8 << 20, max_seq=64, block_size=24,
                       cache_dtype=jnp.float32)


def test_plan_placement_exact_budget_counts_scratch_block(tmp_path, served):
    """A budget with room for the weights plus exactly max_seq tokens of
    KV must be rejected on the paged path: the arena's +1 scratch block
    would oversubscribe it (the pre-fix sizing handed out every block)."""
    _, cfg = served
    (tmp_path / "report.json").write_text(json.dumps(
        {"bytes_after": 1 << 20, "params_before": 1000,
         "params_after": 600}))
    (tmp_path / "config.json").write_text(json.dumps(config_to_dict(cfg)))
    per_tok = 2 * 2 * 2 * 16 * 4
    exact = (1 << 20) + 64 * per_tok        # weights + one sequence, no slack
    with pytest.raises(ValueError, match="scratch"):
        plan_placement(tmp_path, exact, max_seq=64, block_size=8,
                       cache_dtype=jnp.float32, headroom=0.0)
    # one extra block of budget is enough: scratch fits, one slot planned
    place = plan_placement(tmp_path, exact + 8 * per_tok, max_seq=64,
                           block_size=8, cache_dtype=jnp.float32,
                           headroom=0.0)
    assert place.serve.max_slots == 1
    assert place.serve.n_blocks == 64 // 8
    arena_bytes = (place.serve.n_blocks + 1) * 8 * per_tok
    assert place.weights_bytes + arena_bytes <= exact + 8 * per_tok
