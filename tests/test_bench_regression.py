"""The benchmark-regression guard's comparison rules: ±tolerance bands
around committed references, hard min/max floors, loud failure on
missing gated metrics — and the committed baseline itself must parse
and only gate metrics run.py actually emits."""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.regression import DEFAULT_BASELINE, check  # noqa: E402


def _metrics(**rows):
    return {"rows": rows}


def test_ref_band():
    base = {"tolerance": 0.2, "metrics": {"a.x": {"ref": 1.0}}}
    assert not check(_metrics(a={"x": 1.0}), base)
    assert not check(_metrics(a={"x": 1.19}), base)
    assert not check(_metrics(a={"x": 0.81}), base)
    assert check(_metrics(a={"x": 1.3}), base)
    assert check(_metrics(a={"x": 0.7}), base)


def test_min_max_floors():
    base = {"metrics": {"a.speedup": {"min": 1.2},
                        "a.launches": {"max": 1.0}}}
    assert not check(_metrics(a={"speedup": 1.2, "launches": 1.0}), base)
    fails = check(_metrics(a={"speedup": 1.1, "launches": 3.0}), base)
    assert len(fails) == 2
    assert any("below floor" in f for f in fails)
    assert any("above ceiling" in f for f in fails)


def test_missing_metric_fails_loudly():
    base = {"metrics": {"gone.x": {"min": 0.0}}}
    fails = check(_metrics(a={"x": 1.0}), base)
    assert fails and "missing" in fails[0]


def test_committed_baseline_is_wellformed():
    with open(DEFAULT_BASELINE) as f:
        base = json.load(f)
    assert 0.0 < base["tolerance"] < 1.0
    assert base["metrics"], "baseline gates nothing"
    # every gated row must be a benchmark run.py emits
    from benchmarks import run as bench_run
    src = open(bench_run.__file__).read()
    for key, rule in base["metrics"].items():
        row, _, metric = key.partition(".")
        assert f'"{row}"' in src, f"baseline gates unknown row {row!r}"
        assert metric, key
        assert set(rule) <= {"ref", "min", "max"}, (key, rule)
    # the acceptance criteria stay pinned: grouped >= 1.2x the loop,
    # exactly one launch per projection vs E
    assert base["metrics"]["moe_kernel_bench.grouped_vs_loop"]["min"] >= 1.2
    g = base["metrics"]["moe_kernel_bench.grouped_launches_per_proj"]
    assert g["max"] == 1.0
    e = base["metrics"]["moe_kernel_bench.loop_launches_per_proj"]
    assert e["min"] >= 2.0
