"""Sparse × quantized kernels: the int8 kept-tile path.

The numerics oracle is *bitwise* identity: pow2 per-tile scales commute
with every float rounding in the accumulation, so each quantized kernel
(block-sparse, grouped, ragged) must equal the unquantized kernel run
over the fake-quant (dequantised) weights exactly — in f32 AND bf16.
On top of that: per-tile round-trip properties, the per-input-row RTN
regression for ``core.quant``, the recipe→plans→artifact→engine
threading of the quant flag, and mixed quant+grouped+ragged serving
token identity through both engines.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.core.artifact import PrunedArtifact
from repro.core.pipeline import MosaicPipeline
from repro.core.quant import (INT8_MAXQ, QUANT_MODES, dequantize_array,
                              dequantize_tiles, quantize_array,
                              quantize_tiles)
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.models import transformer as T
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig, MoESpec)
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine, make_sparse_mlp_apply
from repro.serve.scheduler import Request
from repro.serve.sparse import (dequantized_weight, pack_expert_projection,
                                pack_projection, plans_from_host,
                                plans_to_host, sparse_linear)

BLOCK = 16


def _block_structured(key, K, N, block=BLOCK, keep=0.4, dtype=jnp.float32):
    kw, km = jax.random.split(key)
    w = jax.random.normal(kw, (K, N), dtype)
    bm = jax.random.uniform(km, (K // block, N // block)) < keep
    return jnp.where(jnp.repeat(jnp.repeat(bm, block, 0), block, 1), w, 0)


# --------------------------------------------------- per-tile round trip

@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.floats(min_value=-8.0, max_value=8.0))
def test_quantize_tiles_properties(seed, log_mag):
    """Positive pow2 scales; per-element error bounded by amax/127; an
    all-zero tile quantises to zeros with scale 1."""
    rng = np.random.default_rng(seed)
    tiles = rng.normal(scale=2.0 ** log_mag, size=(3, 8, 8)).astype(
        np.float32)
    tiles[0] = 0.0
    q, scales = quantize_tiles(tiles)
    assert q.dtype == np.int8 and scales.dtype == np.float32
    assert (scales > 0).all()
    # pow2: log2 is integral
    np.testing.assert_array_equal(np.log2(scales),
                                  np.round(np.log2(scales)))
    assert scales[0] == 1.0 and not q[0].any()
    back = dequantize_tiles(q, scales)
    amax = np.abs(tiles).max(axis=(1, 2))
    bound = amax / INT8_MAXQ + 1e-12
    assert (np.abs(back - tiles).max(axis=(1, 2)) <= bound).all()
    assert (np.abs(q) <= INT8_MAXQ).all()


def test_quantize_tiles_bf16_roundtrip_exact():
    """int8 magnitudes × pow2 scales carry no mantissa bits beyond bf16:
    casting the fake-quant tiles to bf16 and back loses nothing."""
    rng = np.random.default_rng(0)
    q, scales = quantize_tiles(rng.normal(size=(4, BLOCK, BLOCK)))
    fq = dequantize_tiles(q, scales)
    back = np.asarray(jnp.asarray(fq).astype(jnp.bfloat16).astype(
        jnp.float32))
    np.testing.assert_array_equal(fq, back)


# ------------------------------------------- group-wise RTN (core.quant)

def test_quantize_array_groups_per_input_row():
    """Groups run along input rows within one output column — a huge
    outlier in column 0 must not inflate column 1's error."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=(64, 2)).astype(np.float32)
    w[0, 0] = 1e4                             # outlier confined to col 0
    back = dequantize_array(*quantize_array(jnp.asarray(w), bits=8,
                                            group=32))
    err = np.abs(np.asarray(back) - w)
    assert err[:, 1].max() < 0.05             # col 1 unaffected
    assert err[:32, 0].max() > 0.5            # col 0's group pays for it
    assert err[32:, 0].max() < 0.05           # but only the outlier group


def test_quantize_array_shapes_and_padding():
    w = jnp.asarray(np.random.default_rng(2).normal(size=(40, 3, 5)),
                    jnp.float32)
    q, scale, shape, pad = quantize_array(w, bits=8, group=16)
    assert q.shape == (15, 3, 16) and pad == 8     # ceil(40/16) groups
    assert scale.shape == (15, 3, 1)
    back = dequantize_array(q, scale, shape, pad)
    assert back.shape == w.shape
    assert float(jnp.abs(back - w).max()) < 0.05


def test_quantize_model_stats_pinned():
    """Compression stats from real per-column scale counts: 8-bit with
    group=16 on this config stays within the analytic band."""
    from repro.core.quant import quantize_model
    cfg = _cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    qp, stats = quantize_model(params, cfg, bits=8, group=16)
    assert stats["bits"] == 8
    # 2-D projections hit the analytic 16/(8+1) = 1.78x; (E, K, N)
    # expert weights fold E as the group axis, whose short columns pay
    # more scale overhead — the blend on this config is pinned here
    assert stats["compression"] == pytest.approx(1.488, rel=0.02)
    # fake-quant round trip keeps shapes/dtypes
    w0 = params["blocks"][0]["mlp"]["up"]
    assert qp["blocks"][0]["mlp"]["up"].shape == w0.shape


# ------------------------------------------------ kernel bitwise identity

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse_quant_bitwise(dtype):
    w = _block_structured(jax.random.PRNGKey(0), 64, 48)
    p = pack_projection(w, BLOCK, quant="int8")
    wfq = jnp.asarray(dequantized_weight(p, 64), dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 64), dtype)
    y_q = sparse_linear(x, wfq, p, interpret=True, quant="int8")
    y_ref = sparse_linear(x, wfq, p, interpret=True, quant="none")
    assert y_q.dtype == y_ref.dtype
    np.testing.assert_array_equal(np.asarray(y_q), np.asarray(y_ref))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_quant_bitwise(dtype):
    from repro.serve.sparse import grouped_sparse_linear
    E, M, K, N = 3, 8, 64, 48
    keys = jax.random.split(jax.random.PRNGKey(2), E + 1)
    w = jnp.stack([_block_structured(keys[e], K, N) for e in range(E)])
    p = pack_expert_projection(w, BLOCK, quant="int8")
    wfq = jnp.stack([jnp.asarray(dequantized_weight(p.expert(e), K), dtype)
                     for e in range(E)])
    xs = jax.random.normal(keys[-1], (E, M, K), dtype)
    y_q = grouped_sparse_linear(xs, wfq, p, quant="int8")
    y_ref = grouped_sparse_linear(xs, wfq, p, quant="none")
    np.testing.assert_array_equal(np.asarray(y_q), np.asarray(y_ref))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_quant_bitwise(dtype):
    from repro.kernels.grouped_block_sparse.ops import RAGGED_BLOCK_ROWS
    from repro.serve.sparse import ragged_sparse_linear
    E, K, N = 3, 64, 48
    keys = jax.random.split(jax.random.PRNGKey(3), E + 1)
    w = jnp.stack([_block_structured(keys[e], K, N) for e in range(E)])
    p = pack_expert_projection(w, BLOCK, ragged=True, quant="int8")
    wfq = jnp.stack([jnp.asarray(dequantized_weight(p.expert(e), K), dtype)
                     for e in range(E)])
    n_tiles = 4                              # experts 0,1 live; one dead
    tile_expert = jnp.asarray([0, 1, 1, -1], jnp.int32)
    xp = jax.random.normal(keys[-1], (n_tiles * RAGGED_BLOCK_ROWS, K),
                           dtype)
    y_q = ragged_sparse_linear(xp, wfq, tile_expert, p, quant="int8")
    y_ref = ragged_sparse_linear(xp, wfq, tile_expert, p, quant="none")
    live = np.repeat(np.asarray(tile_expert) >= 0, RAGGED_BLOCK_ROWS)
    np.testing.assert_array_equal(np.asarray(y_q)[live],
                                  np.asarray(y_ref)[live])


def test_dequantized_weight_matches_tile_storage():
    """Scattered kept tiles reproduce exactly the fake-quant of the
    planned weight; pruned tiles stay zero."""
    w = _block_structured(jax.random.PRNGKey(4), 64, 48)
    p = pack_projection(w, BLOCK, quant="int8")
    wfq = dequantized_weight(p, 64)
    # zero wherever the plan has no tile
    counts = np.asarray(p.counts)
    kept = np.zeros((64 // BLOCK, 48 // BLOCK), bool)
    idx = np.asarray(p.indices)
    for n in range(counts.shape[0]):
        for s in range(int(counts[n])):
            kept[int(idx[n, s]), n] = True
    mask = np.repeat(np.repeat(kept, BLOCK, 0), BLOCK, 1)
    assert not wfq[~mask].any()
    # kept tiles match a direct tile-by-tile round trip
    err = np.abs(wfq - np.asarray(w))
    assert err.max() <= np.abs(np.asarray(w)).max() / INT8_MAXQ + 1e-12


# ----------------------------------------- recipe → plans → artifact flow

def _cfg() -> ModelConfig:
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
    return ModelConfig(
        name="quant-kernels-test", d_model=64, vocab=256,
        vocab_pad_multiple=16,
        pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),
                 LayerSpec(attn, MoESpec(n_experts=4, top_k=2, d_ff=64))),
        n_periods=1, scan_layers=False, remat=False)


@pytest.fixture(scope="module")
def quant_artifact(tmp_path_factory):
    cfg = _cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.6, category="unstructured",
                         selector="wanda_block", block=BLOCK,
                         ragged_moe=True, quant="int8",
                         calibration=CalibrationSpec(4, 2, 16))
    art = MosaicPipeline(recipe).run(params, cfg)
    d = str(tmp_path_factory.mktemp("quant-bundle"))
    art.save(d)
    return art, PrunedArtifact.load(d)


def test_recipe_quant_validation():
    with pytest.raises(ValueError, match="quant"):
        PruneRecipe(arch="llama3-8b", p=0.5, quant="fp4")
    assert PruneRecipe(arch="llama3-8b", p=0.5).quant == "none"
    assert "int8" in QUANT_MODES and "none" in QUANT_MODES


def test_quant_flag_reaches_plans_and_report(quant_artifact):
    art, _ = quant_artifact
    assert art.recipe.quant == "int8"
    assert art.report["pack"]["quant"] == "int8"
    qb = art.report["pack"]["quant_bytes"]
    assert qb["per_projection"] and qb["total_bytes"] > 0
    for row in qb["per_projection"].values():
        assert row["tile_bytes"] > 0 and row["bytes"] > row["tile_bytes"]
    assert qb["ratio_vs_bf16"] < 0.5
    assert art.report["bytes_after"] < art.report["bytes_before"]
    for p in art.packed.values():
        assert p.quant == "int8"
        assert p.tiles is not None and p.tiles.dtype == jnp.int8
        assert p.scales is not None and p.slots is not None


def test_quant_plans_host_roundtrip(quant_artifact):
    art, loaded = quant_artifact
    back = plans_from_host(*plans_to_host(art.packed))
    for store in (back, loaded.packed):
        assert set(store) == set(art.packed)
        for k, p in art.packed.items():
            b = store[k]
            assert b.quant == "int8" and b.tiles.dtype == jnp.int8
            np.testing.assert_array_equal(np.asarray(b.tiles),
                                          np.asarray(p.tiles))
            np.testing.assert_array_equal(np.asarray(b.scales),
                                          np.asarray(p.scales))
            np.testing.assert_array_equal(np.asarray(b.slots),
                                          np.asarray(p.slots))


def test_params_are_fake_quantized_at_pack(quant_artifact):
    """stage_pack replaces quantized projections' weights with their
    kept-tile round trip, so dense forward == quantized kernels."""
    art, _ = quant_artifact
    p = art.packed[(0, "up")]
    w = np.asarray(art.params["blocks"][0]["mlp"]["up"], np.float32)
    np.testing.assert_array_equal(
        w.reshape(w.shape[0], -1),
        dequantized_weight(p, w.shape[0]))


# --------------------------------------------------- serving token paths

def test_quant_serving_token_identical(quant_artifact):
    """int8 vs dequantized reference, static engine, in-memory AND
    loaded — all four token streams identical."""
    art, loaded = quant_artifact
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                art.cfg.vocab)
    kw = dict(max_seq=24, compute_dtype=jnp.float32,
              cache_dtype=jnp.float32)

    def gen(params, cfg, packed, quant):
        eng = Engine(params, cfg, ServeConfig(**kw, quant=quant),
                     packed=packed)
        return np.asarray(eng.generate(prompt, 8))

    ref = gen(art.params, art.cfg, art.packed, "none")
    for params, cfg, packed in ((art.params, art.cfg, art.packed),
                                (loaded.params, loaded.cfg, loaded.packed)):
        np.testing.assert_array_equal(ref, gen(params, cfg, packed, "int8"))
        np.testing.assert_array_equal(ref, gen(params, cfg, packed, None))


def test_quant_continuous_engine_token_identical(quant_artifact):
    """Mixed quant + grouped + ragged through the continuous engine:
    in-memory int8, loaded int8, and the reference path all agree."""
    art, loaded = quant_artifact
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, (n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate([5, 9, 7])]
    kw = dict(max_slots=2, max_seq=32, compute_dtype=jnp.float32,
              cache_dtype=jnp.float32)
    engines = {
        "mem-int8": ContinuousEngine(art.params, art.cfg,
                                     ServeConfig(**kw, quant="int8"),
                                     packed=art.packed),
        "load-int8": ContinuousEngine.from_artifact(
            loaded, ServeConfig(**kw, quant="int8")),
        "load-ref": ContinuousEngine.from_artifact(
            loaded, ServeConfig(**kw, quant="none")),
    }
    outs = {}
    for label, eng in engines.items():
        finished, _ = eng.run(reqs)
        outs[label] = sorted((f.request.uid, tuple(f.tokens))
                             for f in finished)
    assert outs["mem-int8"] == outs["load-int8"] == outs["load-ref"]


def test_serve_config_quant_validation(quant_artifact):
    art, _ = quant_artifact
    with pytest.raises(ValueError, match="quant"):
        ServeConfig(quant="fp8")
    assert ServeConfig().quant is None
    # int8 demanded of plans without tile storage fails up front
    bare = {k: dataclasses.replace(p, quant="none", tiles=None,
                                   scales=None, slots=None)
            for k, p in art.packed.items()}
    with pytest.raises(ValueError, match="int8"):
        make_sparse_mlp_apply(bare, quant="int8")
    make_sparse_mlp_apply(art.packed, quant="int8")   # plans carry tiles
