"""Sweep subsystem: RankArtifact save/load fidelity, single-pass
profiling, profile-once/prune-many regression (incl. token-identical
1-point sweep vs a direct pipeline run on both serve paths), and the
Pareto report contract."""
import csv
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.sweep as sweep_mod
from repro.common.tree import iter_paths
from repro.core.artifact import PrunedArtifact
from repro.core.pipeline import MosaicPipeline
from repro.core.rank_controller import (RankArtifact, ensure_hessians,
                                        profile_model)
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.core.sweep import (GridSpec, annotate_pareto, pareto_csv,
                              point_label, run_sweep)
from repro.models import transformer as T
from repro.serve.engine import Engine
from tests.conftest import small_config


def _calib(cfg, n=2, batch=2, seq=16):
    return [jax.random.randint(jax.random.PRNGKey(100 + i), (batch, seq),
                               0, cfg.vocab) for i in range(n)]


@pytest.fixture(scope="module")
def model():
    cfg = small_config()           # d_model=64, d_ff=128: tileable @16
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def base_recipe(cfg, **kw):
    kw.setdefault("p", 0.5)
    kw.setdefault("category", "composite")
    kw.setdefault("selector", "wanda_block")
    kw.setdefault("align_channels", 16)
    kw.setdefault("block", 16)
    kw.setdefault("calibration", CalibrationSpec(4, 2, 16))
    return PruneRecipe(arch=cfg.name, **kw)


# -------------------------------------------------- RankArtifact on disk

def test_rank_artifact_roundtrip_with_hessians(model, tmp_path):
    cfg, params = model
    ra = profile_model(params, cfg, _calib(cfg), want_hessians=True)
    d = str(tmp_path / "profile")
    ra.save(d)
    assert RankArtifact.is_artifact(d)
    lr = RankArtifact.load(d)
    assert lr.n_tokens == ra.n_tokens
    assert lr.weights == ra.weights
    assert lr.profile_seconds == pytest.approx(ra.profile_seconds)
    assert set(lr.rank) == set(ra.rank)
    for k, v in ra.rank.items():
        assert isinstance(lr.rank[k], float) == isinstance(v, float)
        np.testing.assert_array_equal(np.asarray(lr.rank[k]),
                                      np.asarray(v))
    assert set(lr.anorms) == set(ra.anorms)
    for k in ra.anorms:
        np.testing.assert_array_equal(np.asarray(lr.anorms[k]),
                                      np.asarray(ra.anorms[k]))
    assert lr.hessians is not None and set(lr.hessians) == set(ra.hessians)
    for k in ra.hessians:
        np.testing.assert_array_equal(np.asarray(lr.hessians[k]),
                                      np.asarray(ra.hessians[k]))


def test_rank_artifact_roundtrip_without_hessians(model, tmp_path):
    cfg, params = model
    ra = profile_model(params, cfg, _calib(cfg))
    d = str(tmp_path / "nohess")
    ra.save(d)
    lr = RankArtifact.load(d)
    assert lr.hessians is None
    assert lr.rank == pytest.approx(ra.rank)


def test_rank_artifact_load_rejects_non_bundle(tmp_path):
    with pytest.raises(FileNotFoundError):
        RankArtifact.load(str(tmp_path / "missing"))


def test_loaded_profile_drives_sparsegpt_identically(model, tmp_path):
    cfg, params = model
    ra = profile_model(params, cfg, _calib(cfg), want_hessians=True)
    d = str(tmp_path / "sg")
    ra.save(d)
    loaded = RankArtifact.load(d)
    recipe = base_recipe(cfg, category="unstructured", selector="sparsegpt",
                         stages=("plan", "prune", "report"))
    a1 = MosaicPipeline(recipe).run(params, cfg, rank_artifact=ra)
    a2 = MosaicPipeline(recipe).run(params, cfg, rank_artifact=loaded)
    for (p1, l1), (p2, l2) in zip(iter_paths(a1.params),
                                  iter_paths(a2.params)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# ------------------------------------------------- single-pass profiling

def test_profile_single_calibration_pass(model, monkeypatch):
    """want_hessians must NOT trigger a second calibration pass."""
    cfg, params = model
    import repro.core.calibrate as C
    calls = []
    real = C.calibrate

    def counting(params, cfg, batches, mode="ssq"):
        calls.append(mode)
        return real(params, cfg, batches, mode=mode)

    monkeypatch.setattr(C, "calibrate", counting)
    ra = profile_model(params, cfg, _calib(cfg), want_hessians=True)
    assert calls == ["both"]
    assert ra.hessians is not None


def test_profile_consumes_generator_once(model):
    """The calibration iterable is consumed once — a generator works."""
    cfg, params = model
    batches = _calib(cfg)
    ra_gen = profile_model(params, cfg, iter(batches), want_hessians=True)
    ra_list = profile_model(params, cfg, batches, want_hessians=True)
    assert ra_gen.n_tokens == ra_list.n_tokens
    assert ra_gen.rank == pytest.approx(ra_list.rank)
    for k in ra_list.hessians:
        np.testing.assert_array_equal(np.asarray(ra_gen.hessians[k]),
                                      np.asarray(ra_list.hessians[k]))


def test_single_pass_matches_separate_passes(model):
    """Tap mode 'both' == ssq-mode stats + hessian-mode stats exactly."""
    cfg, params = model
    batches = _calib(cfg)
    ra_ssq = profile_model(params, cfg, batches)
    ra_both = profile_model(params, cfg, batches, want_hessians=True)
    assert ra_ssq.rank == pytest.approx(ra_both.rank)
    for k in ra_ssq.anorms:
        np.testing.assert_array_equal(np.asarray(ra_ssq.anorms[k]),
                                      np.asarray(ra_both.anorms[k]))
    lazy = ensure_hessians(ra_ssq, params, cfg, batches)
    for k in ra_both.hessians:
        np.testing.assert_array_equal(np.asarray(lazy.hessians[k]),
                                      np.asarray(ra_both.hessians[k]))
    # no-op when hessians already present (same object back)
    assert ensure_hessians(ra_both, params, cfg, batches) is ra_both


# ------------------------------------------------------------- grid spec

def test_grid_points_and_json_roundtrip():
    g = GridSpec(p=(0.3, 0.5), category=("composite", "unstructured"))
    base = PruneRecipe(arch="x", p=0.9, selector="wanda")
    pts = g.points(base)
    assert len(pts) == 4 == g.n_points()
    assert {r.p for r in pts} == {0.3, 0.5}
    assert {r.category for r in pts} == {"composite", "unstructured"}
    assert all(r.selector == "wanda" for r in pts)   # inherited from base
    assert GridSpec.from_json(g.to_json()) == g
    with pytest.raises(ValueError):
        GridSpec.from_dict({"alpha": [1.0]})
    with pytest.raises(ValueError):          # scalar, not a list of values
        GridSpec.from_dict({"category": "composite"})
    with pytest.raises(ValueError):
        GridSpec.from_dict({"p": 0.5})


def test_point_labels_unique_axes():
    r = PruneRecipe(arch="x", p=0.5, category=None, granularity="layer")
    assert point_label(r) == "p0.5-auto-wanda-layer"


# ------------------------------------------- profile-once / prune-many

def test_sweep_profiles_once_and_reports(model, tmp_path, monkeypatch):
    cfg, params = model
    calls = []
    real = sweep_mod.profile_model

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(sweep_mod, "profile_model", counting)
    out = str(tmp_path / "sweep")
    grid = GridSpec(p=(0.4, 0.6), category=("composite", "unstructured"))
    res = run_sweep(base_recipe(cfg), grid, params, cfg, out_dir=out,
                    calibration=_calib(cfg))
    assert len(calls) == 1                      # E5: one profile, N points
    assert res.profiled
    assert len(res.rows) == 4
    for row in res.rows:
        assert row["ppl"] > 0
        assert 0.0 <= row["acc"] <= 100.0
        assert row["bytes_after"] > 0
        assert row["prune_seconds"] is not None
        assert PrunedArtifact.is_artifact(row["artifact_dir"])
    assert RankArtifact.is_artifact(os.path.join(out, "profile"))
    assert any(r["pareto"] for r in res.rows)
    with open(res.csv_path) as f:
        rows = list(csv.DictReader(f))
    assert len(rows) == 4
    for needed in ("ppl", "acc", "bytes_after", "prune_seconds",
                   "quality_per_byte", "pareto"):
        assert all(r[needed] != "" for r in rows), needed
    assert os.path.exists(res.md_path)


def test_sweep_quant_axis(model, tmp_path):
    """The quant grid axis: points fan out over precision, every row
    carries a quant column, and int8 points report real (smaller)
    bytes_after than their unquantized twin."""
    cfg, params = model
    out = str(tmp_path / "sweep-quant")
    grid = GridSpec(quant=("none", "int8"))
    base = base_recipe(cfg, category="unstructured")
    assert {r.quant for r in grid.points(base)} == {"none", "int8"}
    labels = [point_label(r) for r in grid.points(base)]
    assert len(set(labels)) == 2                # int8 visible in the label
    res = run_sweep(base, grid, params, cfg, out_dir=out,
                    calibration=_calib(cfg))
    by_quant = {r["quant"]: r for r in res.rows}
    assert set(by_quant) == {"none", "int8"}
    assert by_quant["int8"]["bytes_after"] < by_quant["none"]["bytes_after"]
    with open(res.csv_path) as f:
        rows = list(csv.DictReader(f))
    assert {r["quant"] for r in rows} == {"none", "int8"}


def test_sweep_reuses_saved_profile_without_profiling(model, tmp_path,
                                                      monkeypatch):
    cfg, params = model
    ra = profile_model(params, cfg, _calib(cfg))
    d = str(tmp_path / "profile")
    ra.save(d)
    monkeypatch.setattr(sweep_mod, "profile_model",
                        lambda *a, **k: pytest.fail("re-profiled!"))
    res = run_sweep(base_recipe(cfg), GridSpec(p=(0.3, 0.6)), params, cfg,
                    rank_artifact=RankArtifact.load(d),
                    calibration=_calib(cfg))
    assert not res.profiled
    assert len(res.rows) == 2


def test_sweep_lazy_hessians_for_sparsegpt_points(model, monkeypatch):
    """A Hessian-free saved profile gains Hessians lazily (one hessian
    pass), not via a full re-profile."""
    cfg, params = model
    ra = profile_model(params, cfg, _calib(cfg))
    assert ra.hessians is None
    monkeypatch.setattr(sweep_mod, "profile_model",
                        lambda *a, **k: pytest.fail("re-profiled!"))
    grid = GridSpec(selector=("wanda", "sparsegpt"))
    res = run_sweep(base_recipe(cfg, category="unstructured",
                                stages=("rank", "plan", "prune", "report")),
                    grid, params, cfg, rank_artifact=ra,
                    calibration=_calib(cfg))
    assert res.rank_artifact.hessians is not None
    assert ra.hessians is None                  # input not mutated
    assert len(res.rows) == 2


def test_one_point_sweep_token_identical_to_direct_run(model, tmp_path):
    """Regression: sweeping a single point == running the pipeline
    directly, down to generated tokens on dense AND sparse serve paths."""
    cfg, params = model
    calib = _calib(cfg)
    recipe = base_recipe(cfg)
    direct = MosaicPipeline(recipe).run(params, cfg, calibration=calib)
    res = run_sweep(recipe, GridSpec(), params, cfg,
                    out_dir=str(tmp_path / "one"), calibration=calib)
    assert len(res.rows) == 1
    loaded = PrunedArtifact.load(res.rows[0]["artifact_dir"])
    for (p1, l1), (p2, l2) in zip(iter_paths(direct.params),
                                  iter_paths(loaded.params)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    prompt = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                                cfg.vocab)

    def gen(params_, cfg_, packed):
        eng = Engine(params_, cfg_, max_seq=16, compute_dtype=jnp.float32,
                     cache_dtype=jnp.float32, packed=packed)
        return np.asarray(eng.generate(prompt, 6))

    np.testing.assert_array_equal(gen(direct.params, direct.cfg, None),
                                  gen(loaded.params, loaded.cfg, None))
    np.testing.assert_array_equal(
        gen(direct.params, direct.cfg, direct.packed),
        gen(loaded.params, loaded.cfg, loaded.packed))


def test_sweep_resume_skips_existing_points(model, tmp_path, monkeypatch):
    """Re-running a sweep over the same out_dir re-executes nothing:
    rows come back from the saved report.json bundles. --fresh /
    resume=False forces re-execution."""
    cfg, params = model
    out = str(tmp_path / "sweep")
    grid = GridSpec(p=(0.4, 0.6))
    first = run_sweep(base_recipe(cfg), grid, params, cfg, out_dir=out,
                      calibration=_calib(cfg))
    ran = []
    orig_run = MosaicPipeline.run

    def counting_run(self, *a, **k):
        ran.append(1)
        return orig_run(self, *a, **k)

    monkeypatch.setattr(MosaicPipeline, "run", counting_run)
    # a fully-resumed re-run must not even profile
    monkeypatch.setattr(sweep_mod, "profile_model",
                        lambda *a, **k: pytest.fail("re-profiled!"))
    msgs = []
    second = run_sweep(base_recipe(cfg), grid, params, cfg, out_dir=out,
                       calibration=_calib(cfg), progress=msgs.append)
    assert not ran                             # every point was resumed
    assert not second.profiled and second.rank_artifact is None
    assert any("resume: skipped 2/2" in m for m in msgs)
    by_label = {r["label"]: r for r in first.rows}
    for row in second.rows:
        ref = by_label[row["label"]]
        assert row["ppl"] == pytest.approx(ref["ppl"])
        assert row["bytes_after"] == ref["bytes_after"]
        assert row["point_seconds"] == 0.0
    # resume=False re-executes every point
    third = run_sweep(base_recipe(cfg), grid, params, cfg, out_dir=out,
                      rank_artifact=first.rank_artifact,
                      calibration=_calib(cfg), resume=False)
    assert len(ran) == 2
    assert all(r["point_seconds"] > 0 for r in third.rows)


def test_sweep_resume_invalidates_on_recipe_change(model, tmp_path):
    """A bundle only resumes when its saved recipe.json equals the
    current point recipe: the label doesn't encode fields like block,
    so editing the base recipe must re-execute, not serve stale rows."""
    cfg, params = model
    out = str(tmp_path / "sweep")
    grid = GridSpec(p=(0.5,))
    first = run_sweep(base_recipe(cfg), grid, params, cfg, out_dir=out,
                      calibration=_calib(cfg))
    # same label (p/category/selector unchanged), different spread
    changed = run_sweep(base_recipe(cfg, spread=0.1), grid, params, cfg,
                        out_dir=out, rank_artifact=first.rank_artifact,
                        calibration=_calib(cfg))
    assert changed.rows[0]["point_seconds"] > 0      # re-executed
    # unchanged recipe resumes as usual
    again = run_sweep(base_recipe(cfg, spread=0.1), grid, params, cfg,
                      out_dir=out, rank_artifact=first.rank_artifact,
                      calibration=_calib(cfg))
    assert again.rows[0]["point_seconds"] == 0.0
    # a truncated report.json (killed mid-save) re-executes, not crashes
    with open(os.path.join(again.rows[0]["artifact_dir"],
                           "report.json"), "w") as f:
        f.write('{"ppl": 1.2, "by')
    healed = run_sweep(base_recipe(cfg, spread=0.1), grid, params, cfg,
                       out_dir=out, rank_artifact=first.rank_artifact,
                       calibration=_calib(cfg))
    assert healed.rows[0]["point_seconds"] > 0
    assert healed.rows[0]["ppl"] is not None


# ---------------------------------------------------------- pareto logic

def test_annotate_pareto_front():
    rows = [
        {"ppl": 10.0, "acc": 50.0, "bytes_after": 1000},   # dominated
        {"ppl": 8.0, "acc": 55.0, "bytes_after": 900},     # dominates ^
        {"ppl": 20.0, "acc": 40.0, "bytes_after": 500},    # smallest
        {"ppl": 7.0, "acc": 60.0, "bytes_after": 2000},    # best quality
    ]
    annotate_pareto(rows)
    assert [r["pareto"] for r in rows] == [False, True, True, True]
    assert rows[0]["quality_per_byte"] == pytest.approx(
        50.0 / (1000 / 2 ** 20))
    text = pareto_csv(rows[:1])
    assert text.splitlines()[0].startswith("label,arch,p,")


def test_annotate_pareto_handles_missing_quality():
    rows = [{"ppl": None, "acc": None, "bytes_after": 100},
            {"ppl": 5.0, "acc": 10.0, "bytes_after": 100}]
    annotate_pareto(rows)
    assert rows[0]["pareto"] is False and rows[0]["quality_per_byte"] is None
    assert rows[1]["pareto"] is True
