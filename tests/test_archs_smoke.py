"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward + one train step + one decode step on CPU; output
shapes asserted, NaN-free."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.models import transformer as T
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    fe = (jax.random.normal(key, (2, 4, cfg.d_model))
          if cfg.frontend else None)

    # forward
    params = T.init_model(key, cfg)
    logits, _, _ = T.forward(params, cfg, toks, frontend_embeds=fe,
                             compute_dtype=jnp.float32)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one train step
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(key, cfg, opt_cfg)
    step = make_train_step(cfg, opt_cfg, compute_dtype=jnp.float32)
    state, metrics = step(state, toks[:, :-1], toks[:, 1:],
                          fe[:, :3] if fe is not None else None)
    assert float(metrics["loss"]) > 0 and not jnp.isnan(metrics["loss"])

    # one decode step against a fresh cache
    cache = T.init_cache(cfg, 2, 32, dtype=jnp.float32)
    _, cache, _ = T.forward(params, cfg, toks, cache=cache,
                            cache_index=jnp.int32(0),
                            compute_dtype=jnp.float32)
    l1, _, _ = T.forward(params, cfg, toks[:, :1], cache=cache,
                         cache_index=jnp.int32(16),
                         compute_dtype=jnp.float32)
    assert l1.shape == (2, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(l1).any())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_shapes_sane(arch):
    """Full configs instantiate as shapes only (eval_shape, no allocation)."""
    import math
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    n = sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
    assert n > 1e9, f"{arch}: suspiciously few params ({n})"
    assert cfg.padded_vocab % cfg.vocab_pad_multiple == 0
