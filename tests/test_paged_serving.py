"""Paged KV pool: block allocator + copy-on-write, prefix sharing,
chunked prefill, admission backpressure, and token-identity of the paged
continuous engine against the contiguous pool — dense, block-sparse and
grouped-MoE, in-memory and from a loaded artifact — plus the redesigned
ServeConfig construction surface (traced per-slot sampling: mixed
temperatures without retracing, per-request seeds independent of batch
composition)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine
from repro.serve.paging import BlockAllocator, OutOfBlocks, PrefixCache
from repro.serve.scheduler import Request, Scheduler


# ------------------------------------------------------------ ServeConfig

def test_serveconfig_validation_and_derived():
    cfg = ServeConfig(max_slots=4, max_seq=64, block_size=8)
    assert cfg.paged and cfg.blocks_per_seq == 8
    assert cfg.arena_blocks == 4 * 64 // 8          # contiguous byte budget
    assert ServeConfig(max_seq=64, block_size=8, n_blocks=5).arena_blocks == 5
    assert not ServeConfig().paged
    with pytest.raises(ValueError):
        ServeConfig(max_seq=60, block_size=8)       # not a block multiple
    with pytest.raises(ValueError):
        ServeConfig(max_seq=64, block_size=8, prefill_chunk=12)
    with pytest.raises(ValueError):
        ServeConfig(prefill_chunk=16)               # chunking needs paging
    with pytest.raises(ValueError):
        ServeConfig(paged_kernel=True)              # kernel needs paging
    assert ServeConfig(max_seq=64, block_size=8,
                       paged_kernel=True).paged_kernel


def test_legacy_kwarg_constructors_warn(served):
    params, cfg = served
    with pytest.warns(DeprecationWarning):
        Engine(params, cfg, 32, compute_dtype=jnp.float32)
    with pytest.warns(DeprecationWarning):
        ContinuousEngine(params, cfg, max_slots=2, max_seq=32,
                         compute_dtype=jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Engine(params, cfg, ServeConfig(max_seq=32))
        ContinuousEngine(params, cfg,
                         ServeConfig(max_slots=2, max_seq=32))


# --------------------------------------------------------- block allocator

def test_allocator_alloc_release_refcount():
    a = BlockAllocator(n_blocks=4, block_size=8)
    assert a.n_free == 4 and a.scratch == 4
    b = a.alloc(3)
    assert len(set(b)) == 3 and a.n_free == 1
    assert all(a.refcount(x) == 1 for x in b)
    a.retain(b[:1])
    assert a.refcount(b[0]) == 2
    a.release(b)                        # shared block survives one release
    assert a.refcount(b[0]) == 1 and a.n_free == 3
    a.release(b[:1])
    assert a.n_free == 4
    with pytest.raises(OutOfBlocks):
        a.alloc(5)
    with pytest.raises(ValueError):
        a.release([0])                  # not allocated
    with pytest.raises(ValueError):
        a.retain([0])


def test_copy_on_write_shared_block():
    a = BlockAllocator(n_blocks=4, block_size=2)
    pool = {"k": jnp.arange(10, dtype=jnp.float32).reshape(5, 2)}
    (b,) = a.alloc(1)
    a.retain([b])                       # two readers
    table = np.array([b, a.scratch], np.int32)
    pool2 = a.ensure_writable(table, 0, pool)
    fresh = int(table[0])
    assert fresh != b                   # writer got a private copy
    np.testing.assert_array_equal(np.asarray(pool2["k"][fresh]),
                                  np.asarray(pool["k"][b]))
    assert a.refcount(b) == 1 and a.refcount(fresh) == 1
    # exclusive blocks are left alone
    assert a.ensure_writable(table, 0, pool2) is pool2
    assert int(table[0]) == fresh


def test_cow_at_zero_free_blocks_uses_reserve():
    """COW against a full arena: without an admission-time reserve the
    allocator raises OutOfBlocks mid-tick (the pre-fix failure, which
    killed serve_forever); with the reserve the copy always succeeds."""
    a = BlockAllocator(n_blocks=3, block_size=2)
    pool = {"k": jnp.arange(8, dtype=jnp.float32).reshape(4, 2)}
    (shared,) = a.alloc(1)
    a.retain([shared])              # a second reader (prefix cache)
    (reserve,) = a.alloc(1)         # claimed at admission for COW
    a.alloc(1)                      # the rest of the arena is busy
    assert a.n_free == 0
    table = np.array([shared, a.scratch], np.int32)
    with pytest.raises(OutOfBlocks):
        a.ensure_writable(table, 0, pool)
    assert int(table[0]) == shared          # failure mutated nothing
    pool2 = a.ensure_writable(table, 0, pool, reserve=reserve)
    assert int(table[0]) == reserve
    np.testing.assert_array_equal(np.asarray(pool2["k"][reserve]),
                                  np.asarray(pool["k"][shared]))
    assert a.refcount(shared) == 1 and a.refcount(reserve) == 1


def test_prefix_cache_share_and_mismatch():
    a = BlockAllocator(n_blocks=8, block_size=4)
    pc = PrefixCache(a)
    assert pc.shareable_tokens(range(8)) == 4   # writer keeps its tail
    assert pc.shareable_tokens(range(9)) == 8
    assert pc.shareable_tokens(range(4)) == 0
    prompt = list(range(100, 110))              # 10 tokens -> 2 full blocks
    owned = a.alloc(3)
    pc.register("sys", prompt, owned)
    assert len(pc) == 1 and a.refcount(owned[0]) == 2
    assert pc.match("sys", prompt) == owned[:2]
    assert pc.match("sys", prompt[:9] + [999]) == owned[:2]  # same prefix
    # divergent tail: longest block-aligned common run still shares
    assert pc.match("sys", prompt[:6] + [777, 778, 779, 780]) == owned[:1]
    assert pc.match("sys", [999] + prompt[1:]) == []    # token mismatch
    assert pc.match("other", prompt) == []
    assert pc.match(None, prompt) == []
    pc.register("sys", [1, 2, 3, 4, 5], owned)  # first writer wins
    assert pc.match("sys", prompt) == owned[:2]
    a.release(owned)
    pc.drop_all()                               # cache's own refs released
    assert a.n_free == 8 and len(pc) == 0


def test_scheduler_prefilling_state_and_backpressure():
    s = Scheduler(max_slots=4, max_seq=32)
    for i in range(3):
        s.submit(Request(uid=i, prompt=[1, 2, 3], max_new_tokens=2))
    slots = s.admissions(can_admit=lambda r: r.uid < 2)
    assert [sl.request.uid for sl in slots] == [0, 1]   # head 2 held, FIFO
    assert set(s.prefilling) == {sl.index for sl in slots}
    assert s.concurrency() == 2 and not s.slots and s.has_work()
    s.started(slots[0], first_token=7)
    assert slots[0].index in s.slots
    assert slots[0].index not in s.prefilling
    assert s.concurrency() == 2                 # one decoding + one prefilling


# ----------------------------------------------- paged vs contiguous serve

@pytest.fixture(scope="module")
def served():
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
    cfg = ModelConfig(name="pgd", d_model=64, vocab=256,
                      vocab_pad_multiple=16,
                      pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),),
                      n_periods=2, scan_layers=False, remat=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


BASE = dict(max_slots=3, max_seq=32, compute_dtype=jnp.float32,
            cache_dtype=jnp.float32, prefill_multiple=4)


def _mixed_requests(vocab=256, n_new=8):
    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(1, vocab, (n,)).tolist(),
                    max_new_tokens=n_new)
            for i, n in enumerate([5, 11, 3, 17, 9, 2])]


def _tokens(finished):
    return [f.tokens for f in sorted(finished, key=lambda f: f.request.uid)]


def test_paged_matches_contiguous_dense(served):
    params, cfg = served
    ref, _ = ContinuousEngine(params, cfg, ServeConfig(**BASE)).run(
        _mixed_requests())
    for extra in ({"block_size": 8},            # one-shot prefill
                  {"block_size": 8, "prefill_chunk": 8},   # chunked
                  {"block_size": 4, "n_blocks": 30}):      # odd arena
        got, stats = ContinuousEngine(
            params, cfg, ServeConfig(**BASE, **extra)).run(_mixed_requests())
        assert _tokens(got) == _tokens(ref), extra
        assert stats.rejected == 0


def test_prefix_sharing_identical_and_counted(served):
    params, cfg = served
    rng = np.random.default_rng(1)
    prefix = rng.integers(1, 256, (19,)).tolist()
    reqs = lambda: [Request(uid=i, prompt=prefix + [50 + i],  # noqa: E731
                            max_new_tokens=6, prefix_id="sys")
                    for i in range(5)]
    ref, _ = ContinuousEngine(params, cfg, ServeConfig(**BASE)).run(reqs())
    got, stats = ContinuousEngine(
        params, cfg,
        ServeConfig(**BASE, block_size=8, prefill_chunk=8)).run(reqs())
    assert _tokens(got) == _tokens(ref)
    # later requests mapped the registered prompt blocks instead of
    # prefilling them: 19-token prompt -> 2 shareable full blocks
    shared = [f.prompt_blocks_shared
              for f in sorted(got, key=lambda f: f.request.uid)]
    assert max(shared) == 2 and stats.prompt_blocks_shared >= 4
    assert 0 < stats.prefix_hit_rate <= 1
    assert stats.prefill_chunks > stats.prefills    # chunking really ran


def test_paged_kernel_token_identical_dense(served):
    """The fused Pallas decode kernel is token-identical to the gather
    path (which is itself token-identical to the contiguous pool) across
    one-shot, chunked, and odd-arena paged configs."""
    params, cfg = served
    ref, _ = ContinuousEngine(params, cfg, ServeConfig(**BASE)).run(
        _mixed_requests())
    for extra in ({"block_size": 8},
                  {"block_size": 8, "prefill_chunk": 8},
                  {"block_size": 4, "n_blocks": 30}):
        got, stats = ContinuousEngine(
            params, cfg,
            ServeConfig(**BASE, paged_kernel=True, **extra)
        ).run(_mixed_requests())
        assert _tokens(got) == _tokens(ref), extra
        assert stats.rejected == 0


def test_paged_kernel_prefix_sharing_identical(served):
    """Fused kernel under prefix sharing: decode reads shared arena
    blocks through several slots' tables and must match the gather
    path token-for-token."""
    params, cfg = served
    rng = np.random.default_rng(1)
    prefix = rng.integers(1, 256, (19,)).tolist()
    reqs = lambda: [Request(uid=i, prompt=prefix + [50 + i],  # noqa: E731
                            max_new_tokens=6, prefix_id="sys")
                    for i in range(5)]
    serve = dict(**BASE, block_size=8, prefill_chunk=8)
    ref, _ = ContinuousEngine(params, cfg, ServeConfig(**serve)).run(reqs())
    got, stats = ContinuousEngine(
        params, cfg,
        ServeConfig(**serve, paged_kernel=True)).run(reqs())
    assert _tokens(got) == _tokens(ref)
    assert stats.prompt_blocks_shared >= 4 and stats.rejected == 0


def test_cow_reserve_claimed_at_admission(served, monkeypatch):
    """Satellite regression: the COW copy block must be pre-claimed at
    admission for prefix-sharing requests, so ``ensure_writable`` never
    allocates inside the tick loop. The spy (a) asserts sharing slots
    carry a reserve even at zero free blocks, and (b) *forces* the COW
    path (unreachable organically: only pre-tail prompt blocks are ever
    shared) by simulating a stale reader — exercising the
    reserve-consumption and ownership-swap bookkeeping end to end."""
    params, cfg = served
    calls, cow = [], []
    orig = BlockAllocator.ensure_writable

    def spy(self, table, j, pool, reserve=None):
        calls.append((self.n_free, reserve))
        if reserve is not None and int(table[j]) != reserve:
            b = int(table[j])
            self.retain([b])            # stale reader forces the copy
            pool = orig(self, table, j, pool, reserve=reserve)
            self.release([b])
            assert int(table[j]) == reserve
            cow.append(b)
            return pool
        return orig(self, table, j, pool, reserve=reserve)

    monkeypatch.setattr(BlockAllocator, "ensure_writable", spy)
    rng = np.random.default_rng(4)
    prefix = rng.integers(1, 256, (17,)).tolist()
    reqs = lambda: [Request(uid=i, prompt=prefix + [60 + i],  # noqa: E731
                            max_new_tokens=6, prefix_id="sys")
                    for i in range(4)]
    # 2 slots x 3 blocks fills the 6-block arena exactly; the second
    # wave shares 2 prefix blocks and still fits (1 owned + 1 reserve)
    serve = ServeConfig(**{**BASE, "max_slots": 2}, block_size=8,
                        prefill_chunk=8, n_blocks=6)
    fin, stats = ContinuousEngine(params, cfg, serve).run(reqs())
    assert len(fin) == 4 and stats.rejected == 0
    assert cow, "forced COW never fired"
    # sharing slots reached the COW guard with zero free blocks AND a
    # pre-claimed reserve: the pre-fix code would have raised OutOfBlocks
    assert any(free == 0 and r is not None for free, r in calls)
    # token identity survives the forced copies
    monkeypatch.setattr(BlockAllocator, "ensure_writable", orig)
    ref, _ = ContinuousEngine(params, cfg, serve).run(reqs())
    assert _tokens(fin) == _tokens(ref)


def test_admission_backpressure_out_of_blocks(served):
    params, cfg = served
    # arena of 8 blocks, each request needs 4 (16-token cap / bs 4):
    # only 2 requests can hold cache at once even with 3 slots free
    serve = ServeConfig(**{**BASE, "max_seq": 16}, block_size=4, n_blocks=8)
    reqs = [Request(uid=i, prompt=[7] * 6, max_new_tokens=10)
            for i in range(5)]
    finished, stats = ContinuousEngine(params, cfg, serve).run(reqs)
    assert len(finished) == 5 and stats.rejected == 0
    assert stats.peak_concurrency == 2
    # FIFO: completion order == arrival order under backpressure
    assert [f.request.uid for f in
            sorted(finished, key=lambda f: f.finished_at)] == list(range(5))


def test_oversized_request_rejected_not_deadlocked(served):
    params, cfg = served
    serve = ServeConfig(**{**BASE, "max_seq": 16}, block_size=4, n_blocks=2)
    reqs = [Request(uid=0, prompt=[7] * 6, max_new_tokens=10),  # needs 4
            Request(uid=1, prompt=[7] * 2, max_new_tokens=2)]   # needs 1
    finished, stats = ContinuousEngine(params, cfg, serve).run(reqs)
    assert stats.rejected == 1
    assert [f.request.uid for f in finished] == [1]


def test_paged_rejects_hybrid_configs():
    from tests.conftest import small_config
    cfg = small_config(mamba=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousEngine(params, cfg,
                         ServeConfig(max_slots=2, max_seq=32, block_size=8,
                                     prefill_multiple=1))


# ------------------------------------- block-sparse / MoE paged fast path

@pytest.fixture(scope="module")
def pruned_moe(tmp_path_factory):
    """Mosaic-pruned dense-MLP + MoE model, saved and reloaded."""
    from repro.core.artifact import PrunedArtifact
    from repro.core.pipeline import MosaicPipeline
    from repro.core.recipe import CalibrationSpec, PruneRecipe
    from tests.test_moe_sparse import moe_config
    cfg = moe_config()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.65, category="unstructured",
                         selector="wanda_block", block=16,
                         calibration=CalibrationSpec(4, 2, 16))
    art = MosaicPipeline(recipe).run(params, cfg)
    d = str(tmp_path_factory.mktemp("paged-moe"))
    art.save(d)
    return art, PrunedArtifact.load(d)


def test_paged_sparse_moe_token_identical(pruned_moe):
    """The paged pool composes with the block-sparse serving fast path:
    dense-contiguous == sparse-paged (grouped MoE kernel), in-memory and
    rehydrated from the artifact bundle."""
    art, loaded = pruned_moe
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, (n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate([5, 9, 7])]
    kw = dict(max_slots=2, max_seq=32, compute_dtype=jnp.float32,
              cache_dtype=jnp.float32)
    ref, _ = ContinuousEngine(art.params, art.cfg,
                              ServeConfig(**kw)).run(reqs)
    paged = ServeConfig(**kw, block_size=8, prefill_chunk=8)
    fused = ServeConfig(**kw, block_size=8, prefill_chunk=8,
                        paged_kernel=True)
    variants = {
        "mem-sparse": ContinuousEngine(art.params, art.cfg, paged,
                                       packed=art.packed),
        "load-sparse": ContinuousEngine.from_artifact(loaded, paged),
        "mem-sparse-kernel": ContinuousEngine(art.params, art.cfg, fused,
                                              packed=art.packed),
        "load-sparse-kernel": ContinuousEngine.from_artifact(loaded, fused),
    }
    for label, eng in variants.items():
        got, stats = eng.run(reqs)
        assert _tokens(got) == _tokens(ref), label
        assert stats.rejected == 0


# ------------------------------------------------- traced per-slot sampling

def test_mixed_temperatures_do_not_retrace(served):
    params, cfg = served
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, prompt=rng.integers(1, 256, (4,)).tolist(),
                    max_new_tokens=6, temperature=t, seed=i)
            for i, t in enumerate([0.0, 0.7, 1.3])]
    eng = ContinuousEngine(params, cfg, ServeConfig(**BASE))
    finished, _ = eng.run(reqs)
    assert len(finished) == 3
    # temperature is a traced vector, not a static arg: one trace total
    assert eng._decode_sample._cache_size() == 1
    # and the greedy request really decoded greedily
    ref, _ = eng.run([Request(uid=0, prompt=reqs[0].prompt,
                              max_new_tokens=6)])
    assert _tokens(finished)[0] == ref[0].tokens


def test_request_seed_independent_of_batch(served):
    params, cfg = served
    probe = lambda uid: Request(uid=uid, prompt=[9, 8, 7],  # noqa: E731
                                max_new_tokens=6, temperature=0.9, seed=123)
    eng = ContinuousEngine(params, cfg, ServeConfig(**BASE))
    alone, _ = eng.run([probe(0)])
    noise = [Request(uid=i, prompt=[i + 1] * 5, max_new_tokens=6,
                     temperature=0.5, seed=i) for i in range(1, 3)]
    crowded, _ = eng.run([probe(0)] + noise)
    assert alone[0].tokens == _tokens(crowded)[0]
    # same stream on the paged pool too
    paged = ContinuousEngine(params, cfg, ServeConfig(**BASE, block_size=8))
    crowded_paged, _ = paged.run([probe(0)] + noise)
    assert alone[0].tokens == _tokens(crowded_paged)[0]
