"""Optional-``hypothesis`` shim for the property tests.

When ``hypothesis`` is installed (the ``[test]`` extra) the real
``given`` / ``settings`` / ``strategies`` are re-exported unchanged.
Without it, a tiny deterministic fallback runs each property test over a
fixed number of seeded pseudo-random examples instead of failing at
collection — tier-1 (`pytest -x -q`) must pass on a bare
``pip install -e .`` plus pytest.

Only the strategy surface the suite uses is implemented: ``floats``,
``integers``, ``lists``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in hypothesis-free CI
    HAVE_HYPOTHESIS = False

    import inspect
    import random

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value=0, max_value=100, **_kw):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples=_FALLBACK_EXAMPLES, **_kw):
        def deco(fn):
            fn._fallback_max_examples = min(max_examples, 25)
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples",
                            getattr(fn, "_fallback_max_examples",
                                    _FALLBACK_EXAMPLES))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            # hide the generated params from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[:-len(strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco
