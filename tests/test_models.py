"""Model substrate behaviour: shapes, decode consistency, attention paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import transformer as T
from tests.conftest import small_config


def _toks(cfg, b=2, s=16, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab)


@pytest.mark.parametrize("scan", [False, True])
def test_forward_shapes(scan):
    cfg = small_config(scan=scan, moe=True, mamba=True)
    p = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg)
    logits, cache, aux = T.forward(p, cfg, toks, compute_dtype=jnp.float32)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert cache is None
    assert not bool(jnp.isnan(logits).any())


def test_scan_equals_unrolled():
    cfg = small_config(scan=True, moe=False, mamba=True)
    p = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg)
    lo_scan, _, _ = T.forward(p, cfg, toks, compute_dtype=jnp.float32)
    cfg_u = cfg.unrolled()
    # re-layout stacked params into per-layer list
    blocks = []
    for period in range(cfg.n_periods):
        for j in range(len(cfg.pattern)):
            blocks.append(jax.tree.map(lambda x: x[period],
                                       p["blocks"][j]))
    p_u = dict(p)
    p_u["blocks"] = blocks
    lo_unroll, _, _ = T.forward(p_u, cfg_u, toks, compute_dtype=jnp.float32)
    np.testing.assert_allclose(lo_scan, lo_unroll, rtol=2e-5, atol=2e-5)


def test_decode_matches_full_forward():
    cfg = small_config(moe=False, mamba=True)
    p = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg)
    full, _, _ = T.forward(p, cfg, toks, compute_dtype=jnp.float32)
    cache = T.init_cache(cfg, 2, 16, dtype=jnp.float32)
    lp, cache, _ = T.forward(p, cfg, toks[:, :8], cache=cache,
                             cache_index=jnp.int32(0),
                             compute_dtype=jnp.float32)
    outs = [lp]
    for i in range(8, 16):
        li, cache, _ = T.forward(p, cfg, toks[:, i:i + 1], cache=cache,
                                 cache_index=jnp.int32(i),
                                 compute_dtype=jnp.float32)
        outs.append(li)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_dense():
    B, S, nq, nkv, D = 2, 4096, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, nq, D))
    k = jax.random.normal(ks[1], (B, S, nkv, D))
    v = jax.random.normal(ks[2], (B, S, nkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = L._dense_attention(q, k, v, pos, pos, causal=True)
    chunk = L._chunked_causal_attention(q, k, v, pos)
    np.testing.assert_allclose(dense, chunk, atol=2e-6)


def test_frontend_embeds_replace_prefix():
    cfg = small_config().replace(frontend="vision", frontend_frac=0.25)
    p = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg)
    fe = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model))
    lo1, _, _ = T.forward(p, cfg, toks, frontend_embeds=fe,
                          compute_dtype=jnp.float32)
    lo2, _, _ = T.forward(p, cfg, toks, compute_dtype=jnp.float32)
    # suffix positions must differ only through attention on the prefix
    assert lo1.shape == lo2.shape
    assert bool(jnp.any(lo1 != lo2))


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 4, 32))
    labels = jnp.zeros((1, 4), jnp.int32)
    ce_all = T.cross_entropy(logits, labels)
    ce_masked = T.cross_entropy(logits, labels, vocab=20)
    assert float(ce_masked) == pytest.approx(np.log(20), rel=1e-5)
    assert float(ce_all) == pytest.approx(np.log(32), rel=1e-5)


def test_mamba_state_decode_matches_scan():
    cfg = small_config(moe=False, mamba=True)
    # only the mamba layer pattern
    cfg = cfg.replace(pattern=cfg.pattern[1:], n_periods=2)
    p = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg, s=12)
    full, _, _ = T.forward(p, cfg, toks, compute_dtype=jnp.float32)
    cache = T.init_cache(cfg, 2, 12, dtype=jnp.float32)
    outs = []
    for i in range(12):
        li, cache, _ = T.forward(p, cfg, toks[:, i:i + 1], cache=cache,
                                 cache_index=jnp.int32(i),
                                 compute_dtype=jnp.float32)
        outs.append(li)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=1e-4, atol=1e-4)
