"""Shared benchmark infrastructure.

Trains one small paper-shaped model (LLaMa-3 family, scaled down) on the
synthetic Zipf-Markov corpus, cached on disk so every benchmark reuses it.
CPU container => absolute numbers are small-scale; the *orderings* are the
reproduction targets (see EXPERIMENTS.md §Quality).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.core import evaluate as EV
from repro.core.rank_controller import RankArtifact, run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.models.specs import ModelConfig, scaled_down
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer

CACHE_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench_cache")

VOCAB = 512
SEQ = 64
TRAIN_STEPS = 400


def bench_config() -> ModelConfig:
    cfg = scaled_down(get_config("llama3-8b"), d_model=128, head_dim=32,
                      d_ff=384, vocab=VOCAB, n_periods=4)
    return cfg.replace(name="bench-llama", scan_layers=False)


def corpus() -> SyntheticCorpus:
    return SyntheticCorpus(VOCAB, seed=0)


def get_trained_model(steps: int = TRAIN_STEPS):
    """(cfg, params, corpus) — trained once, cached."""
    cfg = bench_config()
    c = corpus()
    mgr = CheckpointManager(CACHE_DIR, keep=1)
    opt = OptConfig(lr=2e-3, warmup_steps=20, total_steps=steps)
    if mgr.latest_step() == steps:
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        params = mgr.restore(params)
        return cfg, params, c
    tr = Trainer(cfg, opt, c.batches(32, SEQ), ckpt=None,
                 compute_dtype=jnp.float32, prefetch=False)
    tr.run(steps)
    params = tr.state["params"]
    mgr.save(steps, params, blocking=True)
    return cfg, params, c


def perplexity(params, cfg, c: SyntheticCorpus, n_batches: int = 6,
               start: int = 5000) -> float:
    """Thin corpus adapter over :mod:`repro.core.evaluate`."""
    return EV.perplexity(params, cfg,
                         c.batches(8, SEQ, start=start, n=n_batches))


def accuracy(params, cfg, c: SyntheticCorpus, n_batches: int = 4,
             start: int = 6000) -> float:
    """Mean zero-shot next-token accuracy over three held-out "tasks"
    (top-1, top-5, and a shifted-start-distribution split) — the
    small-scale stand-in for the paper's 7-dataset mean. Implementation
    (incl. the shifted-split construction) lives in
    :mod:`repro.core.evaluate` (the pipeline quality stage)."""
    spec = EV.EvalSpec(batch_size=8, seq_len=SEQ, n_ppl=0,
                       n_acc=n_batches, acc_start=start, seed=c.seed)
    b = EV.synthetic_eval_batches(VOCAB, spec)
    return EV.accuracy(params, cfg, b["acc"], b["shifted"])


def rank_artifact(params, cfg, c: SyntheticCorpus, n_samples: int = 32,
                  want_hessians: bool = False) -> RankArtifact:
    calib = c.calibration_batches(n_samples, 8, SEQ)
    return run_ranking_controller(params, cfg, calib,
                                  want_hessians=want_hessians)


def time_call(fn, *args, repeats: int = 5) -> float:
    """Median wall-clock microseconds per call (post-warmup)."""
    fn(*args)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))
