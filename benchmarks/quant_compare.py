"""Table XIII: pruning (Mosaic) vs weight-only quantisation.

RTN group-quantisation at 8/4/3/2 bits vs projection pruning at matched
compression; reports accuracy, perplexity, compression ratio, and a
latency proxy (pruned models shrink compute; quantised models keep dense
fp16 activations — the paper's 0.3-0.5x slowdowns come from dequant
overhead we do not model on CPU, so we report compute bytes instead).
"""
from __future__ import annotations


from benchmarks.common import (accuracy, get_trained_model, perplexity,
                               rank_artifact)
from repro.common.tree import param_bytes
from repro.core.prune_controller import run_pruning_controller
from repro.core.quant import quantize_model


def run_table13():
    cfg, params, c = get_trained_model()
    art = rank_artifact(params, cfg, c)
    rows = [{"method": "dense", "target": "-",
             "acc": accuracy(params, cfg, c),
             "ppl": perplexity(params, cfg, c), "compression": 1.0}]
    for bits in (8, 4, 3, 2):
        qp, stats = quantize_model(params, cfg, bits=bits, group=64)
        rows.append({"method": "quant", "target": f"{bits}bit",
                     "acc": accuracy(qp, cfg, c),
                     "ppl": perplexity(qp, cfg, c),
                     "compression": stats["compression"]})
    for p in (0.2, 0.4, 0.6, 0.8):
        res = run_pruning_controller(params, cfg, art, p,
                                     category="composite",
                                     align_channels=8)
        comp = param_bytes(params) / param_bytes(res.params)
        rows.append({"method": "mosaic", "target": f"{int(p*100)}%",
                     "acc": accuracy(res.params, res.cfg, c),
                     "ppl": perplexity(res.params, res.cfg, c),
                     "compression": comp})
    return rows


def main(fast: bool = True):
    rows = run_table13()
    print("method,target,acc,ppl,compression")
    for r in rows:
        print(f"{r['method']},{r['target']},{r['acc']:.2f},"
              f"{r['ppl']:.2f},{r['compression']:.2f}")
    return rows


if __name__ == "__main__":
    main(fast=False)
