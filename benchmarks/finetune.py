"""E4: LoRA recovery after pruning (Fig 10 / Table VI).

Fine-tunes a LoRA adapter on each granularity's 80%-pruned model and
tracks loss: projection-pruned models should start lower and recover
faster (fewer steps to reach the coarse methods' final loss).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (accuracy, get_trained_model, perplexity,
                               rank_artifact, SEQ)
from repro.core.lora import init_lora, merge_lora
from repro.core.prune_controller import run_pruning_controller
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, apply_updates, init_opt


def finetune_lora(params, cfg, c, steps: int = 60, rank: int = 8,
                  eval_every: int = 10):
    adapters = init_lora(jax.random.PRNGKey(1), params, cfg, rank=rank)

    def loss(ad, tokens, labels):
        merged = merge_lora(params, cfg, ad, rank=rank)
        l, _ = T.loss_fn(merged, cfg, tokens, labels,
                         compute_dtype=jnp.float32)
        return l

    ocfg = OptConfig(lr=5e-3, warmup_steps=5, total_steps=steps,
                     weight_decay=0.0)
    ostate = init_opt(adapters, ocfg)
    gfn = jax.jit(jax.value_and_grad(loss))
    curve = []
    for i, (tokens, labels) in enumerate(
            c.batches(16, SEQ, start=2000, n=steps)):
        l, g = gfn(adapters, tokens, labels)
        adapters, ostate, _ = apply_updates(adapters, g, ostate, ocfg)
        if i % eval_every == 0 or i == steps - 1:
            curve.append((i, float(l)))
    merged = merge_lora(params, cfg, adapters, rank=rank)
    return merged, curve


def run_e4(p: float = 0.8, steps: int = 60):
    cfg, params, c = get_trained_model()
    art = rank_artifact(params, cfg, c)
    out = {}
    for g in ("global", "layer", "projection"):
        res = run_pruning_controller(params, cfg, art, p,
                                     category="unstructured",
                                     granularity=g)
        before = {"ppl": perplexity(res.params, res.cfg, c),
                  "acc": accuracy(res.params, res.cfg, c)}
        merged, curve = finetune_lora(res.params, res.cfg, c, steps=steps)
        after = {"ppl": perplexity(merged, res.cfg, c),
                 "acc": accuracy(merged, res.cfg, c)}
        out[g] = {"before": before, "after": after, "curve": curve}
    return out


def steps_to_reach(curve, target_loss: float):
    for step, l in curve:
        if l <= target_loss:
            return step
    return curve[-1][0]


def main(fast: bool = True):
    res = run_e4(steps=40 if fast else 80)
    print("granularity,ppl_before,ppl_after,acc_before,acc_after,final_loss")
    for g, r in res.items():
        print(f"{g},{r['before']['ppl']:.2f},{r['after']['ppl']:.2f},"
              f"{r['before']['acc']:.2f},{r['after']['acc']:.2f},"
              f"{r['curve'][-1][1]:.3f}")
    # recovery speed: steps for projection to reach global's final loss
    gfinal = res["global"]["curve"][-1][1]
    sp = steps_to_reach(res["projection"]["curve"], gfinal)
    print(f"\n# projection reaches global's final loss at step {sp} "
          f"(global needed {res['global']['curve'][-1][0]})")
    return res


if __name__ == "__main__":
    main(fast=False)
