"""Paged-attention decode kernel benchmark: fused Pallas kernel vs the
gather path.

Since PR 6 every paged decode tick materializes each slot's logical KV
view — ``paged_gather`` copies ``(B, max_blocks*block_size, n_kv, D)``
out of the arena per layer per token, regardless of how few blocks a
sequence actually occupies. The fused kernel prefetches block tables
into scalar memory and gathers K/V blocks inside the kernel, touching
only the blocks below each sequence's length.

Kernel timings are interpret mode on CPU, so absolute tokens/s are not
TPU numbers (they ride along ungated); the reproduction targets are

- agreement: the fused kernel matches the paged reference on ragged
  GQA workloads (``kernel_agrees``), and the engine with
  ``ServeConfig.paged_kernel`` on generates token-identical outputs to
  the gather path on a prefix-shared workload (``token_identical``);
- traffic: the per-tick gathered KV bytes, modeled analytically from
  the workload's decode schedule, strictly drop — the gather path
  moves ``blocks_per_seq`` blocks per slot per tick where the fused
  kernel reads only the live ``ceil(length / block_size)`` blocks
  (``kv_bytes_reduction``, gated in ``baseline.json``).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import counters
from repro.kernels.paged_attention.ops import paged_attention_decode
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models import transformer as T
from repro.models.specs import AttentionSpec, LayerSpec, MLPSpec, ModelConfig
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.scheduler import Request

AGREE_TOL = 5e-6                # fp32 flash-softmax reassociation bound


def bench_model():
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=16)
    cfg = ModelConfig(name="paged-attn-bench", d_model=64, vocab=256,
                      vocab_pad_multiple=16,
                      pattern=(LayerSpec(attn, MLPSpec(d_ff=128)),),
                      n_periods=2, scan_layers=False, remat=False)
    return T.init_model(jax.random.PRNGKey(0), cfg), cfg


def kernel_agreement(B=4, M=4, bs=8, n_kv=2, n_q=4, D=16, seed=3):
    """Fused kernel vs the paged reference on a shuffled arena with
    ragged lengths; returns the max abs error."""
    rng = np.random.default_rng(seed)
    nb = B * M
    q = jnp.asarray(rng.standard_normal((B, 1, n_q, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((nb + 1, bs, n_kv, D)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((nb + 1, bs, n_kv, D)),
                    jnp.float32)
    tables = jnp.asarray(rng.permutation(nb).reshape(B, M), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, M * bs + 1, (B,)), jnp.int32)
    out = paged_attention_decode(q, k, v, tables, lengths, interpret=True)
    ref = paged_attention_ref(q[:, 0], k, v, tables, lengths)[:, None]
    return float(jnp.abs(out - ref).max())


def make_workload(n_requests=6, prefix_len=11, seed=5):
    """Mixed workload: half the requests share a prompt prefix (so the
    fused path also runs over prefix-shared block tables), half are
    unique ragged prompts."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, 250, prefix_len).tolist()
    reqs = []
    for i in range(n_requests):
        if i % 2:
            prompt = prefix + [250 + i % 5]
            pid = "sys"
        else:
            prompt = rng.integers(1, 250, 5 + 3 * i).tolist()
            pid = None
        reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=8,
                            prefix_id=pid))
    return reqs


def decode_kv_bytes(reqs, cfg, serve, cache_dtype=jnp.float32):
    """Analytic per-workload KV read traffic of the decode loop, in
    bytes, for both paths. Machine-independent: derived from the decode
    schedule (one tick per generated token per request), not measured.
    The gather path materializes every slot's full ``blocks_per_seq``
    logical view each tick; the fused kernel reads only the blocks
    below the sequence's current length."""
    bs = serve.block_size
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if isinstance(cfg.layer(i).mixer, AttentionSpec))
    spec = next(cfg.layer(i).mixer for i in range(cfg.n_layers)
                if isinstance(cfg.layer(i).mixer, AttentionSpec))
    block_bytes = (bs * spec.n_kv * spec.head_dim * 2 * n_attn
                   * jnp.dtype(cache_dtype).itemsize)
    gather = fused = 0
    for r in reqs:
        for t in range(1, r.max_new_tokens + 1):
            length = min(len(r.prompt) + t, serve.max_seq)
            gather += serve.blocks_per_seq * block_bytes
            fused += -(-length // bs) * block_bytes
    return gather, fused


def run_engine(params, cfg, serve, reqs):
    eng = ContinuousEngine(params, cfg, serve)
    eng.run(reqs)                       # warm-up: compile
    t0 = time.perf_counter()
    finished, stats = eng.run(reqs)
    wall = time.perf_counter() - t0
    return ({f.request.uid: f.tokens for f in finished},
            stats.generated_tokens / wall)


def main(fast: bool = True):
    params, cfg = bench_model()
    reqs = make_workload(6 if fast else 12)
    gather_cfg = ServeConfig(max_slots=4, max_seq=32, block_size=8,
                             prefill_chunk=8, compute_dtype=jnp.float32,
                             cache_dtype=jnp.float32)
    fused_cfg = dataclasses.replace(gather_cfg, paged_kernel=True)

    gather_out, gather_tps = run_engine(params, cfg, gather_cfg, reqs)
    counters.reset()
    fused_out, fused_tps = run_engine(params, cfg, fused_cfg, reqs)
    # the fused engine's decode-step trace must have dispatched the
    # kernel op (the gather engine never does); this runs before
    # kernel_agreement() below on purpose — a standalone call with the
    # same shapes would warm the op's jit cache and absorb the record
    traced = float(counters.snapshot().get("paged_attention", 0))
    identical = float(gather_out == fused_out)

    err = kernel_agreement()
    agrees = float(err < AGREE_TOL)

    gather_bytes, fused_bytes = decode_kv_bytes(reqs, cfg, gather_cfg)
    ticks = sum(r.max_new_tokens for r in reqs)
    reduction = 1.0 - fused_bytes / gather_bytes

    print(f"workload: {len(reqs)} requests, {ticks} decode ticks, "
          f"block_size {gather_cfg.block_size}, "
          f"{gather_cfg.blocks_per_seq} blocks/seq")
    print(f"{'path':12s} {'tok/s':>10s} {'KV KiB/tick':>12s}")
    for name, tps, nbytes in (("gather", gather_tps, gather_bytes),
                              ("fused", fused_tps, fused_bytes)):
        print(f"{name:12s} {tps:10.1f} {nbytes / ticks / 1024:12.2f}")
    print(f"kernel max err vs ref: {err:.1e} (agrees: {bool(agrees)}); "
          f"fused==gather tokens: {bool(identical)}; "
          f"per-tick KV bytes cut {reduction:.0%}")
    if not identical:
        # hard acceptance criterion — fail the CI bench-smoke job loudly
        raise AssertionError("fused paged-attention decode diverged "
                             "from the gather path")
    return {"kernel_agrees": agrees,
            "kernel_max_err": err,
            "token_identical": identical,
            "kernel_traced": traced,
            "kv_bytes_reduction": reduction,
            "gather_kv_bytes_per_tick": gather_bytes / ticks,
            "fused_kv_bytes_per_tick": fused_bytes / ticks,
            "gather_tokens_per_s": gather_tps,
            "fused_tokens_per_s": fused_tps}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(fast=not args.full)
