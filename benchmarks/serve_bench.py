"""Serving benchmark: static batching vs continuous batching vs the
continuous engine with the block-sparse fast path.

Mixed-length Poisson-arrival workload (uniform prompt lengths and
per-request token budgets). Reports tokens/s, p50/p99 request latency,
and slot utilization per engine. Each engine is timed on its second run
(the first run compiles every shape bucket).

The static baseline processes the queue FIFO in fixed batches of
``max_slots``, right-padding every prompt to the longest in the batch
and decoding until the largest per-request budget in the batch is met —
the head-of-line blocking + padding waste continuous batching removes.
Only requested tokens count toward its tokens/s.

The sparse engine serves Mosaic ``wanda_block``-pruned weights through
the Pallas block-sparse kernel (interpret mode on CPU, so its wall
clock is a correctness/coverage row there — the tile-skip fraction is
the TPU win). The bench asserts its outputs agree exactly with the
dense continuous engine.

The second section is the paged-pool payoff: a shared-system-prompt
Poisson workload (every request = one long common prefix + a short
unique tail) served by the contiguous pool vs the paged pool *at the
same cache-arena byte budget*. The contiguous pool burns a full
``max_seq`` region per slot, so the budget caps it at a handful of
concurrent requests; the paged pool maps the shared prefix blocks once
(refcounted) and spends its budget on tail/decode blocks, serving
several times more concurrent requests — reported as
``paged_concurrency_vs_contiguous`` alongside the prefix-block hit rate,
with outputs asserted token-identical.

The third section is the scheduler-policy shoot-out: one mixed-priority
burst workload (the latency-sensitive cohort arrives *last*) served
under ``fifo`` / ``priority`` / ``slo`` admission, reporting per-policy
p50/p99 queue and total latency plus SLO attainment. Deadlines are
calibrated from fifo's measured wall clock, so
``slo_vs_fifo_attainment`` is machine-speed-free and gated >= 1 in
``baseline.json`` (EDF must never attain less than arrival order).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.models.specs import AttentionSpec, LayerSpec, MLPSpec, ModelConfig
from repro.serve.batching import ContinuousEngine, latency_percentiles
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine
from repro.serve.metrics import queue_percentiles, slo_attainment
from repro.serve.scheduler import Request
from repro.serve.sparse import flop_savings, pack_model


def bench_model(prune: float = 0.6):
    """A small kernel-tileable model, wanda_block-pruned so the sparse
    path has real zero tiles to skip."""
    attn = AttentionSpec(n_q=4, n_kv=2, head_dim=32)
    cfg = ModelConfig(name="serve-bench", d_model=128, vocab=512,
                      vocab_pad_multiple=16,
                      pattern=(LayerSpec(attn, MLPSpec(d_ff=256)),),
                      n_periods=2, scan_layers=False, remat=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    calib = corpus.calibration_batches(4, 2, 32)
    art = run_ranking_controller(params, cfg, calib)
    res = run_pruning_controller(params, cfg, art, prune,
                                 category="unstructured",
                                 selector="wanda_block")
    return res.params, res.cfg, corpus


def make_workload(corpus, n_requests: int, seed: int = 0,
                  prompt_range=(8, 56), new_range=(4, 41),
                  mean_gap_s: float = 0.002):
    """Ranges are chosen so max prompt + max budget fits the static
    baseline's cache: it pads the batch to its longest prompt and
    decodes the largest budget (see ``run_static``'s guard)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        s0 = int(rng.integers(*prompt_range))
        prompt = corpus.batch(i, 1, s0)[0, :s0].tolist()
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(*new_range)),
                            arrival=t))
        t += float(rng.exponential(mean_gap_s))
    return reqs


def make_shared_workload(corpus, n_requests: int, seed: int = 1,
                         prefix_len: int = 192, tail_range=(4, 13),
                         new_range=(6, 13), mean_gap_s: float = 0.0):
    """Shared-system-prompt workload: every request is one long common
    prefix plus a short unique tail, all under the same ``prefix_id`` —
    the chat-serving shape where prompt KV dominates the cache. The
    default gap of 0 is the burst-arrival limit: every request is
    queued at t=0, so the concurrency comparison is purely structural
    (cache budget, not arrival timing, caps the batch)."""
    rng = np.random.default_rng(seed)
    prefix = corpus.batch(7777, 1, prefix_len)[0].tolist()
    t, reqs = 0.0, []
    for i in range(n_requests):
        tl = int(rng.integers(*tail_range))
        tail = corpus.batch(9000 + i, 1, tl)[0, :tl].tolist()
        reqs.append(Request(uid=i, prompt=prefix + tail,
                            max_new_tokens=int(rng.integers(*new_range)),
                            arrival=t, prefix_id="sys"))
        if mean_gap_s:
            t += float(rng.exponential(mean_gap_s))
    return reqs


def make_priority_workload(corpus, n_requests: int, seed: int = 2,
                           prompt_range=(8, 25), new_tokens: int = 8,
                           deadline_ms=None):
    """Mixed-priority burst workload: every request arrives at t=0, the
    *last* ``n_requests // 2`` submissions are the latency-sensitive
    cohort (priority 1, and — once calibrated — a deadline). FIFO
    serves them last because they arrived last; the ``priority`` and
    ``slo`` policies pull them forward. ``deadline_ms`` of None builds
    the calibration pass (no deadlines to miss)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        urgent = i >= n_requests // 2
        s0 = int(rng.integers(*prompt_range))
        prompt = corpus.batch(100 + i, 1, s0)[0, :s0].tolist()
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=new_tokens, arrival=0.0,
            priority=1 if urgent else 0,
            deadline_ms=deadline_ms if urgent else None))
    return reqs


def run_static(eng, reqs, max_slots: int):
    """FIFO fixed batches through the static Engine (arrivals ignored —
    a strictly generous baseline)."""
    t0 = time.perf_counter()
    lats, requested, ticks = [], 0, 0
    for i in range(0, len(reqs), max_slots):
        batch = reqs[i:i + max_slots]
        s_max = max(len(r.prompt) for r in batch)
        n_new = max(r.max_new_tokens for r in batch)
        assert s_max + n_new <= eng.max_seq, (
            "workload overflows the static engine's cache "
            f"({s_max} + {n_new} > {eng.max_seq})")
        prompts = np.zeros((len(batch), s_max), np.int32)
        for j, r in enumerate(batch):
            prompts[j, :len(r.prompt)] = r.prompt
        out = eng.generate(jnp.asarray(prompts), n_new)
        jax.block_until_ready(out)
        done = time.perf_counter() - t0
        lats.extend([done * 1e3] * len(batch))
        requested += sum(r.max_new_tokens for r in batch)
        ticks += n_new
    wall = time.perf_counter() - t0
    util = requested / (max_slots * ticks) if ticks else 0.0
    return {"tokens": requested, "wall_s": wall,
            "tokens_per_s": requested / wall,
            "p50": float(np.percentile(lats, 50)),
            "p99": float(np.percentile(lats, 99)),
            "util": util}


def run_continuous(eng, reqs):
    finished, stats = eng.run(reqs)
    lat = latency_percentiles(finished)
    queue = queue_percentiles(finished)
    return {"tokens": stats.generated_tokens, "wall_s": stats.wall_s,
            "tokens_per_s": stats.tokens_per_s,
            "p50": lat["p50"], "p99": lat["p99"],
            "queue_p50": queue["p50"], "queue_p99": queue["p99"],
            "slo_attainment": slo_attainment(finished),
            "util": stats.slot_utilization,
            "peak_concurrency": stats.peak_concurrency,
            "prefix_hit_rate": stats.prefix_hit_rate,
            "outputs": {f.request.uid: f.tokens for f in finished}}


def main(fast: bool = True):
    n_requests = 12 if fast else 48
    max_slots = 4
    max_seq = 96
    params, cfg, corpus = bench_model()
    packed = pack_model(params, cfg, block=16)
    skip = flop_savings(packed)
    reqs = make_workload(corpus, n_requests)

    static_eng = Engine(params, cfg, max_seq=max_seq,
                        compute_dtype=jnp.float32, cache_dtype=jnp.float32)
    cont_eng = ContinuousEngine(params, cfg, max_slots=max_slots,
                                max_seq=max_seq, compute_dtype=jnp.float32,
                                cache_dtype=jnp.float32)
    sparse_eng = ContinuousEngine(params, cfg, max_slots=max_slots,
                                  max_seq=max_seq,
                                  compute_dtype=jnp.float32,
                                  cache_dtype=jnp.float32, packed=packed)
    rows = []
    runners = [
        ("dense-static", lambda: run_static(static_eng, reqs, max_slots)),
        ("continuous", lambda: run_continuous(cont_eng, reqs)),
        ("continuous+sparse", lambda: run_continuous(sparse_eng, reqs)),
    ]
    outputs = {}
    for name, fn in runners:
        fn()                 # warm-up: compile every shape bucket
        runs = [fn() for _ in range(3)]
        runs.sort(key=lambda r: r["tokens_per_s"])
        r = runs[1]          # median run
        outputs[name] = r.pop("outputs", None)
        r["engine"] = name
        rows.append(r)

    agree = outputs["continuous"] == outputs["continuous+sparse"]
    speedup = (rows[1]["tokens_per_s"] / rows[0]["tokens_per_s"])

    p_lens = [len(r.prompt) for r in reqs]
    budgets = [r.max_new_tokens for r in reqs]
    print(f"workload: {n_requests} requests, prompts "
          f"{min(p_lens)}-{max(p_lens)}, budgets "
          f"{min(budgets)}-{max(budgets)}, {max_slots} slots, "
          f"sparse tile-skip {skip:.0%}")
    print(f"{'engine':18s} {'tok/s':>8s} {'p50ms':>8s} {'p99ms':>8s} "
          f"{'util':>6s}")
    for r in rows:
        print(f"{r['engine']:18s} {r['tokens_per_s']:8.1f} "
              f"{r['p50']:8.0f} {r['p99']:8.0f} {r['util']:6.0%}")
    print(f"continuous vs static: {speedup:.2f}x tokens/s; "
          f"sparse==dense outputs: {agree}")
    if not agree:
        # hard acceptance criterion — fail the CI bench-smoke job loudly
        raise AssertionError("sparse serving diverged from dense")

    # ---- paged pool vs contiguous pool, same cache-arena byte budget
    shared_seq, block, budget_slots = 256, 64, 4
    arena = budget_slots * shared_seq // block      # 16 blocks, same bytes
    # uniform budgets keep the cohort structure deterministic: the first
    # admissions (pre-registration, 4 owned blocks each) retire together,
    # then every remaining request maps the shared prefix and needs one
    # owned block — the arena holds all of them at once
    n_shared = 16
    shared_reqs = make_shared_workload(corpus, n_shared,
                                       new_range=(16, 17))
    cont_eng2 = ContinuousEngine(params, cfg, ServeConfig(
        max_slots=budget_slots, max_seq=shared_seq,
        compute_dtype=jnp.float32, cache_dtype=jnp.float32))
    paged_eng = ContinuousEngine(params, cfg, ServeConfig(
        max_slots=n_shared, max_seq=shared_seq, block_size=block,
        n_blocks=arena, prefill_chunk=block,
        compute_dtype=jnp.float32, cache_dtype=jnp.float32))
    for name, eng in (("contiguous-shared", cont_eng2),
                      ("paged-shared", paged_eng)):
        run_continuous(eng, shared_reqs)            # warm-up
        runs = [run_continuous(eng, shared_reqs) for _ in range(3)]
        runs.sort(key=lambda r: r["tokens_per_s"])
        r = runs[1]
        outputs[name] = r.pop("outputs", None)
        r["engine"] = name
        rows.append(r)
    cont_row, paged_row = rows[-2], rows[-1]
    paged_agrees = outputs["contiguous-shared"] == outputs["paged-shared"]
    conc_ratio = (paged_row["peak_concurrency"]
                  / max(cont_row["peak_concurrency"], 1))
    tok_ratio = paged_row["tokens_per_s"] / cont_row["tokens_per_s"]

    prefix_blocks = len(shared_reqs[0].prompt) // block
    print(f"\nshared-prefix workload: {n_shared} requests, "
          f"{prefix_blocks}-block shared prefix, arena budget "
          f"{budget_slots} x {shared_seq} tokens "
          f"({arena} blocks of {block})")
    for r in rows[-2:]:
        print(f"{r['engine']:18s} {r['tokens_per_s']:8.1f} tok/s  "
              f"peak {r['peak_concurrency']:2d} concurrent  "
              f"hit-rate {r['prefix_hit_rate']:.2f}")
    print(f"paged vs contiguous: {conc_ratio:.2f}x concurrency, "
          f"{tok_ratio:.2f}x tokens/s at the same HBM budget; "
          f"paged==contiguous outputs: {paged_agrees}")
    if not paged_agrees:
        raise AssertionError("paged serving diverged from contiguous")

    # ---- scheduler policy shoot-out: the same mixed-priority burst
    # workload through fifo / priority / slo admission. The urgent
    # cohort *arrives last*, so fifo structurally serves it last; the
    # deadline is calibrated from fifo's measured wall clock (0.7x), so
    # the attainment comparison is machine-speed-free: slo (EDF) admits
    # the deadline carriers first and meets what fifo misses.
    n_pol = 12
    pol_engines = {
        pol: ContinuousEngine(params, cfg, ServeConfig(
            max_slots=max_slots, max_seq=max_seq, scheduler=pol,
            compute_dtype=jnp.float32, cache_dtype=jnp.float32))
        for pol in ("fifo", "priority", "slo")}
    warm = make_priority_workload(corpus, n_pol)
    run_continuous(pol_engines["fifo"], warm)           # compile
    cal = run_continuous(pol_engines["fifo"], warm)     # calibrate
    deadline_ms = cal["wall_s"] * 1e3 * 0.7
    pol_reqs = make_priority_workload(corpus, n_pol,
                                      deadline_ms=deadline_ms)
    pol_rows = []
    for pol, eng in pol_engines.items():
        run_continuous(eng, pol_reqs)                   # warm-up
        runs = [run_continuous(eng, pol_reqs) for _ in range(3)]
        runs.sort(key=lambda r: r["slo_attainment"])
        r = runs[1]
        pol_outputs = r.pop("outputs")
        assert set(pol_outputs) == set(range(n_pol)), \
            f"{pol} dropped requests"
        r["policy"] = pol
        pol_rows.append(r)
    fifo_att = pol_rows[0]["slo_attainment"]
    slo_att = pol_rows[2]["slo_attainment"]
    att_ratio = (slo_att + 1e-6) / (fifo_att + 1e-6)

    print(f"\npolicy workload: {n_pol} burst requests, urgent half "
          f"arrives last (priority 1, deadline {deadline_ms:.0f}ms), "
          f"{max_slots} slots")
    print(f"{'policy':10s} {'q_p50ms':>8s} {'q_p99ms':>8s} {'p50ms':>8s} "
          f"{'p99ms':>8s} {'slo_att':>8s}")
    for r in pol_rows:
        print(f"{r['policy']:10s} {r['queue_p50']:8.0f} "
              f"{r['queue_p99']:8.0f} {r['p50']:8.0f} {r['p99']:8.0f} "
              f"{r['slo_attainment']:8.2f}")
    print(f"slo vs fifo attainment: {att_ratio:.2f}x "
          f"({slo_att:.2f} vs {fifo_att:.2f})")

    return {"rows": rows, "speedup": speedup, "sparse_agrees": agree,
            "flops_skipped": skip, "paged_agrees": paged_agrees,
            "paged_concurrency_vs_contiguous": conc_ratio,
            "paged_vs_contiguous_tokens": tok_ratio,
            "prefix_hit_rate": paged_row["prefix_hit_rate"],
            "policy_rows": pol_rows,
            "fifo_attainment": fifo_att, "slo_attainment": slo_att,
            "slo_vs_fifo_attainment": att_ratio}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(fast=not args.full)
