"""E3: unstructured vs composite vs structured projection pruning.

Table V (perplexity per category x sparsity) + Fig 9 (inference latency
and memory). Latency = measured CPU wall-clock of the jitted forward
(structured/composite models are physically smaller => genuinely faster);
memory = parameter bytes + KV/activation estimate. The TPU-side win for
unstructured-within-composite comes from the block-sparse kernel: we also
report the zero-block fraction the kernel would skip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (get_trained_model, perplexity, rank_artifact,
                               time_call, SEQ)
from repro.common.tree import param_bytes, param_count
from repro.core.prune_controller import run_pruning_controller
from repro.core.registry import projections
from repro.common.tree import tree_get
from repro.kernels.block_sparse.ops import block_mask_from_weight_mask
from repro.models import transformer as T

CATEGORIES = ("unstructured", "composite", "structured")


def zero_block_fraction(params, cfg, block: int = 16) -> float:
    """Fraction of (block x block) weight tiles that are entirely zero —
    what the TPU block-sparse kernel skips."""
    fracs = []
    for proj in projections(cfg):
        w = np.asarray(tree_get(params, proj.path))
        w2 = w.reshape(-1, w.shape[-1])
        K, N = w2.shape
        K2, N2 = K - K % block, N - N % block
        if K2 == 0 or N2 == 0:
            continue
        bm = block_mask_from_weight_mask(w2[:K2, :N2] != 0, block, block)
        fracs.append(1.0 - bm.mean())
    return float(np.mean(fracs)) if fracs else 0.0


def run_e3(sparsities=(0.2, 0.4, 0.6, 0.8)):
    cfg, params, c = get_trained_model()
    art = rank_artifact(params, cfg, c)
    tokens, _ = next(c.batches(8, SEQ, start=7000))
    rows = []

    def fwd_latency(p_, cfg_):
        f = jax.jit(functools.partial(
            lambda pr, t: T.forward(pr, cfg_, t,
                                    compute_dtype=jnp.float32)[0]))
        return time_call(f, p_, tokens)

    base = {"category": "-", "p": 0.0,
            "ppl": perplexity(params, cfg, c),
            "params": param_count(params),
            "bytes": param_bytes(params),
            "latency_us": fwd_latency(params, cfg),
            "zero_blocks": 0.0}
    rows.append(base)
    for cat in CATEGORIES:
        for p in sparsities:
            res = run_pruning_controller(params, cfg, art, p, category=cat,
                                         align_channels=8)
            rows.append({
                "category": cat, "p": p,
                "ppl": perplexity(res.params, res.cfg, c),
                "params": param_count(res.params),
                "bytes": param_bytes(res.params),
                "latency_us": fwd_latency(res.params, res.cfg),
                "zero_blocks": zero_block_fraction(res.params, res.cfg),
            })
    return rows


def main(fast: bool = True):
    rows = run_e3(sparsities=(0.4, 0.8) if fast else (0.2, 0.4, 0.6, 0.8))
    print("category,p,ppl,params,bytes,latency_us,zero_block_frac")
    for r in rows:
        print(f"{r['category']},{r['p']},{r['ppl']:.2f},{r['params']},"
              f"{r['bytes']},{r['latency_us']:.0f},{r['zero_blocks']:.3f}")
    return rows


if __name__ == "__main__":
    main(fast=False)
