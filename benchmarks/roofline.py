"""§Roofline: three-term analysis per (arch x shape) from the dry-run.

  compute term    = HLO_FLOPs(per chip) / peak_FLOP/s
  memory term     = HLO_bytes(per chip) / HBM_bw
  collective term = collective_bytes(per chip) / link_bw

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (prefill/decode); the
MODEL/HLO ratio exposes remat + padding + replication waste.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.launch.analysis import TPU_V5E
from repro.models import transformer as T
from repro.models.specs import MoESpec

N_CHIPS = 256
RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def active_params(cfg) -> float:
    """Matmul-active parameters per token (MoE experts scaled by top_k/E;
    embedding gather excluded, LM head included)."""
    shapes = jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    total = 0.0
    if cfg.scan_layers:
        block_specs = list(enumerate(cfg.pattern))   # leaves carry period axis
        blocks = shapes["blocks"]
    else:
        block_specs = list(enumerate(cfg.layers()))
        blocks = shapes["blocks"]
    for i, spec in block_specs:
        for path_name, sub in blocks[i].items():
            for kname, leaf in _leaves_with_names(sub):
                size = math.prod(leaf.shape)
                if path_name == "moe" and isinstance(spec.ffn, MoESpec) \
                        and kname in ("up", "gate", "down"):
                    size *= spec.ffn.top_k / spec.ffn.n_experts
                total += size
    # LM head (tied or not): one d x V matmul per token
    total += cfg.d_model * cfg.padded_vocab
    return total


def _leaves_with_names(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaves_with_names(v, k)
    else:
        yield prefix, tree


def model_flops(cfg, shape) -> float:
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n * tokens
    tokens = shape.batch * 1
    return 2.0 * n * tokens


def analyse(path: str) -> dict:
    with open(path) as f:
        res = json.load(f)
    if res.get("skipped"):
        return res
    cfg = get_config(res["arch"])
    shape = SHAPES[res["shape"]]
    cost = res["cost"]
    hw = TPU_V5E
    compute_s = cost["flops"] / hw["peak_flops_bf16"]
    memory_s = cost["bytes_accessed"] / hw["hbm_bw"]
    collective_s = cost["collective_bytes"] / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / N_CHIPS
    step_s = max(terms.values())
    ideal_s = mf / hw["peak_flops_bf16"]
    return {
        "arch": res["arch"], "shape": res["shape"], "mesh": res["mesh"],
        **terms, "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_per_chip": mf,
        "useful_ratio": mf / cost["flops"] if cost["flops"] else 0.0,
        "roofline_frac": ideal_s / step_s if step_s else 0.0,
        "hbm_gib": res["memory"]["peak_memory_in_bytes"] / 2 ** 30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(RESULTS, "dryrun"))
    ap.add_argument("--csv", default=os.path.join(RESULTS, "roofline.csv"))
    # tolerate the driver's flags (run.py calls this in-process, so
    # sys.argv carries run.py's own --json/--full/... arguments)
    args, _ = ap.parse_known_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*__single.json"))):
        rows.append(analyse(path))
    hdr = ("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
           "useful_ratio,roofline_frac,peak_hbm_gib")
    lines = [hdr]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"{r['arch']},{r['shape']},skipped:"
                         f"{r['reason'][:40]},,,,,,")
            continue
        lines.append(
            f"{r['arch']},{r['shape']},{r['compute_s']:.4e},"
            f"{r['memory_s']:.4e},{r['collective_s']:.4e},"
            f"{r['bottleneck']},{r['useful_ratio']:.3f},"
            f"{r['roofline_frac']:.3f},{r['hbm_gib']:.2f}")
    out = "\n".join(lines)
    print(out)
    with open(args.csv, "w") as f:
        f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
