"""Grouped MoE kernel benchmark: one launch for all experts vs the
per-expert launch loop vs the dense einsum — plus the decode-tick
occupancy payoff of the ragged dispatch.

Runs the MoE smoke config through the full recipe pipeline (wanda_block,
so every expert weight carries real zero tiles), then times the MoE
feed-forward — routing, dispatch, and combine included — through the
expert-matmul paths. Kernel timings are interpret mode on CPU, so
absolute numbers are not TPU numbers; the reproduction targets are

- launch counts: the grouped path must issue exactly ONE kernel launch
  per projection where the per-expert loop issues E (counted at real
  dispatch, ``repro.kernels.counters``),
- the ordering: grouped >= 1.2x loop tokens/s (dispatch + grid overhead
  the grouping removes — on TPU the dispatch gap is the whole point),
- occupancy: at decode batch sizes the experts-computed counters must
  equal the experts the router actually hit — not E — on BOTH the
  occupancy-masked grouped launch and the ragged dispatch (with
  top_k < E, a single-token tick always leaves experts empty).

All paths must agree to fp32 tolerance vs the dense einsum; grouped,
loop, and ragged must be bitwise identical to each other (same
per-expert accumulation order).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.pipeline import MosaicPipeline
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.kernels import counters
from repro.models import transformer as T
from repro.models.moe import apply_moe
from repro.models.specs import MoESpec
from repro.serve.sparse import (apply_fake_quant, flop_savings, pack_model,
                                quant_plan_bytes, sparse_apply_moe)

N_PROJ = 3                      # gate/up/down — launches counted per proj


def moe_artifact():
    """The MoE smoke config pruned by the standard smoke recipe."""
    cfg = get_smoke_config("qwen3-moe-30b-a3b").replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.6, category="unstructured",
                         selector="wanda_block", block=16, ragged_moe=True,
                         calibration=CalibrationSpec(4, 2, 16))
    return MosaicPipeline(recipe).run(params, cfg)


def _launches(snap: dict) -> int:
    """Kernel launches only — the occupancy counters share the registry
    under ``*_experts_computed`` keys and are not launches."""
    return sum(v for k, v in snap.items()
               if not k.endswith("experts_computed"))


def _time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main(fast: bool = True):
    art = moe_artifact()
    layer = next(i for i in range(art.cfg.n_layers)
                 if isinstance(art.cfg.layer(i).ffn, MoESpec))
    spec = art.cfg.layer(layer).ffn
    block_params = art.params["blocks"][layer]
    B, S = (4, 32) if fast else (8, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, art.cfg.d_model),
                          jnp.float32)
    n_tokens = B * S
    reps = 5 if fast else 9

    def run_dense():
        y, _ = apply_moe(block_params["moe"], spec, x)
        return y

    def run_loop():
        return sparse_apply_moe(block_params, spec, x, art.packed, layer,
                                group_experts=False, ragged_moe=False)

    def run_grouped():
        return sparse_apply_moe(block_params, spec, x, art.packed, layer,
                                group_experts=True, ragged_moe=False)

    rows = []
    outs = {}
    for name, fn in [("dense_einsum", run_dense),
                     ("per_expert_loop", run_loop),
                     ("grouped", run_grouped)]:
        outs[name] = fn()                   # warm-up: compile
        counters.reset()
        fn()
        launches = _launches(counters.snapshot())
        sec = _time(fn, reps)
        rows.append({"path": name, "ms": sec * 1e3,
                     "tokens_per_s": n_tokens / sec,
                     "launches": launches,
                     "launches_per_proj": launches / N_PROJ})

    by = {r["path"]: r for r in rows}
    speedup = (by["grouped"]["tokens_per_s"]
               / by["per_expert_loop"]["tokens_per_s"])
    err = max(float(jnp.abs(outs["dense_einsum"] - outs[p]).max())
              for p in ("per_expert_loop", "grouped"))
    exact = bool(jnp.array_equal(outs["per_expert_loop"], outs["grouped"]))

    # ------------------------------------- decode tick: occupancy payoff
    # A single-token decode tick routes exactly top_k experts; with
    # top_k < E the launch MUST leave the rest uncomputed. The bench
    # runs eagerly, so the occupancy reaching the counters is concrete.
    E = spec.n_experts
    x_dec = jax.random.normal(jax.random.PRNGKey(2), (1, 1, art.cfg.d_model),
                              jnp.float32)
    logits = (x_dec.reshape(1, -1) @ block_params["moe"]["router"]
              ).astype(jnp.float32)
    routed = int(np.unique(
        np.asarray(jax.lax.top_k(logits, spec.top_k)[1])).size)

    def run_dec_dense():
        y, _ = apply_moe(block_params["moe"], spec, x_dec)
        return y

    def run_dec_grouped():
        return sparse_apply_moe(block_params, spec, x_dec, art.packed,
                                layer, group_experts=True, ragged_moe=False)

    def run_dec_ragged():
        return sparse_apply_moe(block_params, spec, x_dec, art.packed,
                                layer, ragged_moe=True)

    dec_outs = {}
    dec_stats = {}
    for name, fn, launch_key in [
            ("decode_grouped", run_dec_grouped, "grouped_block_sparse"),
            ("decode_ragged", run_dec_ragged, "grouped_block_sparse_ragged")]:
        dec_outs[name] = fn()
        counters.reset()
        fn()
        snap = counters.snapshot()
        launches = snap.get(launch_key, 0)
        computed = snap.get(f"{launch_key}_experts_computed", 0)
        sec = _time(fn, reps)
        dec_stats[name] = {
            "launches_per_proj": launches / N_PROJ,
            "experts_per_launch": computed / max(launches, 1),
            "tokens_per_s": 1.0 / sec}
    dec_outs["decode_dense"] = run_dec_dense()

    dec_err = max(float(jnp.abs(dec_outs["decode_dense"] - dec_outs[p]).max())
                  for p in ("decode_grouped", "decode_ragged"))
    err = max(err, dec_err)
    dec_exact = bool(jnp.array_equal(dec_outs["decode_grouped"],
                                     dec_outs["decode_ragged"]))
    occupancy_match = all(
        s["experts_per_launch"] == routed for s in dec_stats.values())
    empty_skipped = routed < E and occupancy_match

    print(f"moe ffn: E={E} top_k={spec.top_k} "
          f"d_ff={spec.d_ff}, {n_tokens} tokens, "
          f"tile-skip {flop_savings(art.packed):.0%}")
    print(f"{'path':18s} {'tok/s':>10s} {'ms':>8s} {'launches':>9s} "
          f"{'per proj':>9s}")
    for r in rows:
        print(f"{r['path']:18s} {r['tokens_per_s']:10.0f} {r['ms']:8.2f} "
              f"{r['launches']:9d} {r['launches_per_proj']:9.1f}")
    print(f"grouped vs per-expert loop: {speedup:.2f}x tokens/s; "
          f"max |dense - sparse| = {err:.1e}; grouped==loop: {exact}")
    print(f"decode tick (1 token, top_k={spec.top_k}): "
          f"{routed}/{E} experts routed")
    for name, s in dec_stats.items():
        print(f"{name:18s} experts/launch={s['experts_per_launch']:.1f} "
              f"launches/proj={s['launches_per_proj']:.1f}")
    # --------------------------------- quant decode tick: int8 kept tiles
    # Re-pack the pruned params with int8 kept-tile storage and fake-
    # quantize the dense weights to the same round-trip, then require the
    # quantized grouped AND ragged launches to be bitwise identical to
    # their dequantized reference paths (pow2 scales make this exact).
    qpacked = pack_model(art.params, art.cfg, block=16,
                         group_experts=True, ragged_moe=True, quant="int8")
    qparams = apply_fake_quant(art.params, art.cfg, qpacked)
    qblock = qparams["blocks"][layer]

    def run_dec_quant(quant, ragged):
        return sparse_apply_moe(qblock, spec, x_dec, qpacked, layer,
                                group_experts=True, ragged_moe=ragged,
                                quant=quant)

    q_outs = {(q, r): run_dec_quant(q, r)
              for q in ("int8", "none") for r in (False, True)}
    counters.reset()
    run_dec_quant("int8", False)
    run_dec_quant("int8", True)
    qsnap = counters.snapshot()
    quant_launches = (qsnap.get("grouped_block_sparse_quant", 0)
                      + qsnap.get("grouped_block_sparse_ragged_quant", 0))
    quant_exact = all(
        bool(jnp.array_equal(q_outs[("int8", r)], q_outs[("none", r)]))
        for r in (False, True)) and bool(
        jnp.array_equal(q_outs[("int8", False)], q_outs[("int8", True)]))
    qbytes = quant_plan_bytes(qpacked, qparams, art.cfg)

    print(f"occupancy match: {occupancy_match}; empty experts skipped: "
          f"{empty_skipped}; ragged==grouped: {dec_exact}")
    print(f"quant decode tick: int8==reference (grouped & ragged): "
          f"{quant_exact}; quant launches/proj="
          f"{quant_launches / (2 * N_PROJ):.1f}; "
          f"bytes ratio vs bf16 dense: {qbytes['ratio_vs_bf16']:.3f}")
    if not quant_exact:
        raise AssertionError(
            "quantized MoE kernels diverged from dequantized reference")
    if not exact:
        # same accumulation order per expert => must be bitwise equal
        raise AssertionError("grouped kernel diverged from per-expert loop")
    if not dec_exact:
        raise AssertionError("ragged dispatch diverged from grouped kernel")
    return {"rows": rows,
            "n_experts": E,
            "grouped_vs_loop": speedup,
            "grouped_launches_per_proj": by["grouped"]["launches_per_proj"],
            "loop_launches_per_proj":
                by["per_expert_loop"]["launches_per_proj"],
            "grouped_tokens_per_s": by["grouped"]["tokens_per_s"],
            "loop_tokens_per_s": by["per_expert_loop"]["tokens_per_s"],
            "dense_tokens_per_s": by["dense_einsum"]["tokens_per_s"],
            "max_err_vs_dense": err,
            "decode_experts_routed": float(routed),
            "decode_grouped_experts_per_launch":
                dec_stats["decode_grouped"]["experts_per_launch"],
            "decode_ragged_experts_per_launch":
                dec_stats["decode_ragged"]["experts_per_launch"],
            "ragged_launches_per_proj":
                dec_stats["decode_ragged"]["launches_per_proj"],
            "decode_occupancy_match": float(occupancy_match),
            "decode_empty_experts_skipped": float(empty_skipped),
            "decode_paths_identical": float(dec_exact),
            "quant_paths_identical": float(quant_exact),
            "quant_bytes_ratio": qbytes["ratio_vs_bf16"],
            "quant_launches_per_proj": quant_launches / (2 * N_PROJ),
            "decode_grouped_tokens_per_s":
                dec_stats["decode_grouped"]["tokens_per_s"],
            "decode_ragged_tokens_per_s":
                dec_stats["decode_ragged"]["tokens_per_s"],
            "prune_seconds": art.report.get("prune_seconds")}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(fast=not args.full)
