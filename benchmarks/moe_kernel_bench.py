"""Grouped MoE kernel benchmark: one launch for all experts vs the
per-expert launch loop vs the dense einsum.

Runs the MoE smoke config through the full recipe pipeline (wanda_block,
so every expert weight carries real zero tiles), then times the MoE
feed-forward — routing, dispatch, and combine included — through the
three expert-matmul paths. Kernel timings are interpret mode on CPU, so
absolute numbers are not TPU numbers; the reproduction targets are

- launch counts: the grouped path must issue exactly ONE kernel launch
  per projection where the per-expert loop issues E (counted at real
  dispatch, ``repro.kernels.counters``), and
- the ordering: grouped >= 1.2x loop tokens/s (dispatch + grid overhead
  the grouping removes — on TPU the dispatch gap is the whole point).

All three paths must agree to fp32 tolerance; grouped vs loop must be
bitwise identical (same per-expert accumulation order).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.pipeline import MosaicPipeline
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.kernels import counters
from repro.models import transformer as T
from repro.models.moe import apply_moe
from repro.models.specs import MoESpec
from repro.serve.sparse import flop_savings, sparse_apply_moe

N_PROJ = 3                      # gate/up/down — launches counted per proj


def moe_artifact():
    """The MoE smoke config pruned by the standard smoke recipe."""
    cfg = get_smoke_config("qwen3-moe-30b-a3b").replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=cfg.name, p=0.6, category="unstructured",
                         selector="wanda_block", block=16,
                         calibration=CalibrationSpec(4, 2, 16))
    return MosaicPipeline(recipe).run(params, cfg)


def main(fast: bool = True):
    art = moe_artifact()
    layer = next(i for i in range(art.cfg.n_layers)
                 if isinstance(art.cfg.layer(i).ffn, MoESpec))
    spec = art.cfg.layer(layer).ffn
    block_params = art.params["blocks"][layer]
    B, S = (4, 32) if fast else (8, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, art.cfg.d_model),
                          jnp.float32)
    n_tokens = B * S

    def run_dense():
        y, _ = apply_moe(block_params["moe"], spec, x)
        return y

    def run_loop():
        return sparse_apply_moe(block_params, spec, x, art.packed, layer,
                                group_experts=False)

    def run_grouped():
        return sparse_apply_moe(block_params, spec, x, art.packed, layer,
                                group_experts=True)

    rows = []
    outs = {}
    for name, fn in [("dense_einsum", run_dense),
                     ("per_expert_loop", run_loop),
                     ("grouped", run_grouped)]:
        outs[name] = fn()                   # warm-up: compile
        counters.reset()
        fn()
        launches = sum(counters.snapshot().values())
        ts = []
        for _ in range(5 if fast else 9):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        sec = float(np.median(ts))
        rows.append({"path": name, "ms": sec * 1e3,
                     "tokens_per_s": n_tokens / sec,
                     "launches": launches,
                     "launches_per_proj": launches / N_PROJ})

    by = {r["path"]: r for r in rows}
    speedup = (by["grouped"]["tokens_per_s"]
               / by["per_expert_loop"]["tokens_per_s"])
    err = max(float(jnp.abs(outs["dense_einsum"] - outs[p]).max())
              for p in ("per_expert_loop", "grouped"))
    exact = bool(jnp.array_equal(outs["per_expert_loop"], outs["grouped"]))

    print(f"moe ffn: E={spec.n_experts} top_k={spec.top_k} "
          f"d_ff={spec.d_ff}, {n_tokens} tokens, "
          f"tile-skip {flop_savings(art.packed):.0%}")
    print(f"{'path':18s} {'tok/s':>10s} {'ms':>8s} {'launches':>9s} "
          f"{'per proj':>9s}")
    for r in rows:
        print(f"{r['path']:18s} {r['tokens_per_s']:10.0f} {r['ms']:8.2f} "
              f"{r['launches']:9d} {r['launches_per_proj']:9.1f}")
    print(f"grouped vs per-expert loop: {speedup:.2f}x tokens/s; "
          f"max |dense - sparse| = {err:.1e}; grouped==loop: {exact}")
    if not exact:
        # same accumulation order per expert => must be bitwise equal
        raise AssertionError("grouped kernel diverged from per-expert loop")
    return {"rows": rows,
            "n_experts": spec.n_experts,
            "grouped_vs_loop": speedup,
            "grouped_launches_per_proj": by["grouped"]["launches_per_proj"],
            "loop_launches_per_proj":
                by["per_expert_loop"]["launches_per_proj"],
            "grouped_tokens_per_s": by["grouped"]["tokens_per_s"],
            "loop_tokens_per_s": by["per_expert_loop"]["tokens_per_s"],
            "dense_tokens_per_s": by["dense_einsum"]["tokens_per_s"],
            "max_err_vs_dense": err,
            "prune_seconds": art.report.get("prune_seconds")}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(fast=not args.full)
