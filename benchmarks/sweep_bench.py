"""Recipe-sweep benchmark: the E5 reuse win as a quality/size Pareto.

One RC profile of the trained bench model fans across a p x category
grid (``repro.core.sweep.run_sweep``); the resulting table is the
repo-scale analogue of the paper's multi-configuration claim — profiling
amortises to ~0 per extra configuration, and every point carries
ppl / acc / bytes_after so the trade-off is explicit, not assumed.
"""
from __future__ import annotations

from benchmarks.common import SEQ, get_trained_model
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.core.sweep import GridSpec, pareto_markdown, run_sweep

FAST_GRID = GridSpec(p=(0.4, 0.7), category=("unstructured", "composite"))
FULL_GRID = GridSpec(p=(0.2, 0.4, 0.6, 0.8),
                     category=("unstructured", "structured", "composite"))


def main(fast: bool = True) -> list:
    cfg, params, c = get_trained_model()
    base = PruneRecipe(arch=cfg.name, p=0.5, category="composite",
                       selector="wanda_block", align_channels=8, block=16,
                       calibration=CalibrationSpec(n_samples=16,
                                                   batch_size=8,
                                                   seq_len=SEQ))
    calib = c.calibration_batches(16, 8, SEQ)
    res = run_sweep(base, FAST_GRID if fast else FULL_GRID, params, cfg,
                    calibration=calib)
    n_pareto = sum(1 for r in res.rows if r["pareto"])
    print(f"profile: once ({res.rank_artifact.profile_seconds:.2f}s) for "
          f"{len(res.rows)} points; {n_pareto} on the Pareto front")
    print(pareto_markdown(res.rows))
    return res.rows


if __name__ == "__main__":
    main(fast=False)
