"""End-to-end recipe-execution benchmark: seconds from dense params to
saved-ready PrunedArtifact per arch (the paper's model-production-time
claim — Mosaic's 7.19x is about *pipeline* speed, so CI tracks it).

Each row runs the full declarative pipeline (rank -> plan -> prune ->
pack -> report) from one PruneRecipe on the arch's smoke config.
"""
from __future__ import annotations

import time

import jax

from repro.configs.registry import get_smoke_config
from repro.core.pipeline import MosaicPipeline
from repro.core.recipe import CalibrationSpec, PruneRecipe

FAST_ARCHS = ("llama3-8b", "gemma-2b")
FULL_ARCHS = FAST_ARCHS + ("phi3-medium-14b", "qwen3-moe-30b-a3b")


def bench_arch(arch: str, p: float = 0.5) -> dict:
    cfg = get_smoke_config(arch).replace(scan_layers=False)
    from repro.models import transformer as T
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    recipe = PruneRecipe(arch=arch, p=p, category="composite",
                         selector="wanda_block", align_channels=16,
                         block=16,
                         calibration=CalibrationSpec(n_samples=8,
                                                     batch_size=4,
                                                     seq_len=32))
    t0 = time.perf_counter()
    artifact = MosaicPipeline(recipe).run(params, cfg)
    seconds = time.perf_counter() - t0
    rep = artifact.report
    return {
        "arch": arch,
        "seconds": seconds,
        "rank_s": rep["profile_seconds"],
        "prune_s": rep["prune_seconds"],
        "pack_s": rep["stage_seconds"].get("pack", 0.0),
        "category": rep["category"],
        "flop_savings": rep["pack"]["flop_savings"],
    }


def main(fast: bool = True) -> list:
    rows = []
    print(f"{'arch':24s} {'total_s':>8s} {'rank_s':>7s} {'prune_s':>8s} "
          f"{'pack_s':>7s} {'skip':>5s}")
    for arch in (FAST_ARCHS if fast else FULL_ARCHS):
        r = bench_arch(arch)
        rows.append(r)
        print(f"{r['arch']:24s} {r['seconds']:8.2f} {r['rank_s']:7.2f} "
              f"{r['prune_s']:8.2f} {r['pack_s']:7.2f} "
              f"{r['flop_savings']:5.0%}")
    return rows


if __name__ == "__main__":
    main()
