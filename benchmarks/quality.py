"""E1 + E2: projection vs layer vs global pruning quality.

Reproduces (at small scale) Table IV / Fig 7 (perplexity + accuracy vs
sparsity per granularity) and Fig 8 (per-layer / per-projection pruning
target distributions).
"""
from __future__ import annotations


from benchmarks.common import (accuracy, get_trained_model, perplexity,
                               rank_artifact)
from repro.core.planner import plan
from repro.core.prune_controller import run_pruning_controller

SPARSITIES = (0.2, 0.4, 0.6, 0.8)
GRANULARITIES = ("global", "layer", "projection")


def run_e1(sparsities=SPARSITIES, selector: str = "sparsegpt"):
    cfg, params, c = get_trained_model()
    art = rank_artifact(params, cfg, c,
                        want_hessians=(selector == "sparsegpt"))
    base_ppl = perplexity(params, cfg, c)
    base_acc = accuracy(params, cfg, c)
    rows = [{"granularity": "-", "p": 0.0, "ppl": base_ppl,
             "acc": base_acc}]
    for g in GRANULARITIES:
        for p in sparsities:
            res = run_pruning_controller(params, cfg, art, p,
                                         category="unstructured",
                                         granularity=g, selector=selector,
                                         )
            rows.append({"granularity": g, "p": p,
                         "ppl": perplexity(res.params, res.cfg, c),
                         "acc": accuracy(res.params, res.cfg, c)})
    return rows


def run_e2(p: float = 0.8):
    """Per-projection target distribution at 80% (Fig 8)."""
    cfg, params, c = get_trained_model()
    art = rank_artifact(params, cfg, c)
    out = {}
    for g in GRANULARITIES:
        out[g] = plan(art.rank, p, granularity=g)
    spreads = {g: (min(t.values()), max(t.values()))
               for g, t in out.items()}
    return out, spreads


def main(fast: bool = True):
    rows = run_e1(sparsities=(0.4, 0.8) if fast else SPARSITIES)
    print("granularity,p,ppl,acc")
    for r in rows:
        print(f"{r['granularity']},{r['p']},{r['ppl']:.2f},{r['acc']:.2f}")
    targets, spreads = run_e2()
    print("\n# E2 target ranges at p=0.8 (min..max per granularity):")
    for g, (lo, hi) in spreads.items():
        print(f"{g}: {lo:.3f}..{hi:.3f}")
    return rows, spreads


if __name__ == "__main__":
    main(fast=False)
