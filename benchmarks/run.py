"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark unit),
followed by each benchmark's detailed table. ``--full`` widens sweeps.
``--json`` additionally writes structured per-row metrics (tokens/s,
prune_seconds, kernel launch counts, ...) — the file the CI
benchmark-regression guard (``benchmarks/regression.py``) compares
against the committed ``benchmarks/baseline.json``.
"""
from __future__ import annotations

import argparse
import io
import json
import os
import sys
import time
from contextlib import redirect_stdout

# make `python benchmarks/run.py` work from anywhere: the repo root (for
# the `benchmarks` package) must be importable alongside src (for repro)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _timed(name: str, fn, *args, **kw):
    buf = io.StringIO()
    t0 = time.perf_counter()
    with redirect_stdout(buf):
        result = fn(*args, **kw)
    dt_us = (time.perf_counter() - t0) * 1e6
    return name, dt_us, result, buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full sparsity sweeps (slower)")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--csv", default=None, metavar="PATH",
                    help="also write the summary CSV to this file")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured per-row metrics (the "
                         "benchmark-regression guard's input)")
    args, _ = ap.parse_known_args()
    fast = not args.full

    from benchmarks import (composite, finetune, kernel_bench,
                            moe_kernel_bench, overheads, paged_attn_bench,
                            prune_pipeline, quality, quant_compare,
                            serve_bench, sweep_bench)

    sections = []
    rows = []
    metrics = {}

    for name, fn in [
        ("table4_fig7_quality_e1_e2", lambda: quality.main(fast)),
        ("table5_fig9_composite_e3", lambda: composite.main(fast)),
        ("fig10_table6_finetune_e4", lambda: finetune.main(fast)),
        ("fig11_fig12_overheads_e5", lambda: overheads.main(fast)),
        ("table13_quant_compare", lambda: quant_compare.main(fast)),
        ("kernel_bench", lambda: kernel_bench.main(fast)),
        ("moe_kernel_bench", lambda: moe_kernel_bench.main(fast)),
        ("paged_attn_bench", lambda: paged_attn_bench.main(fast)),
        ("serve_bench", lambda: serve_bench.main(fast)),
        ("prune_pipeline", lambda: prune_pipeline.main(fast)),
        ("recipe_sweep", lambda: sweep_bench.main(fast)),
    ]:
        nm, us, result, text = _timed(name, fn)
        derived = _derive(name, result)
        rows.append((nm, us, derived))
        metrics[nm] = _metrics(nm, result, us)
        sections.append((nm, text))

    if not args.skip_roofline:
        try:
            from benchmarks import roofline
            nm, us, result, text = _timed("roofline_from_dryrun",
                                          roofline.main)
            ok = [r for r in result if not r.get("skipped")]
            derived = (f"cells={len(ok)}"
                       f";median_roofline_frac="
                       f"{_median([r['roofline_frac'] for r in ok]):.3f}"
                       if ok else "no-dryrun-results")
            rows.append((nm, us, derived))
            sections.append((nm, text))
        except Exception as e:                        # noqa: BLE001
            rows.append(("roofline_from_dryrun", 0.0, f"error:{e!r}"))

    csv_lines = ["name,us_per_call,derived"]
    csv_lines += [f"{nm},{us:.0f},{derived}" for nm, us, derived in rows]
    print("\n".join(csv_lines))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("\n".join(csv_lines) + "\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": metrics}, f, indent=2, sort_keys=True)
            f.write("\n")
    for nm, text in sections:
        print(f"\n===== {nm} =====")
        print(text.rstrip())


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2] if xs else 0.0


def _derive(name: str, result) -> str:
    try:
        if name.startswith("table4"):
            rows, spreads = result
            proj = [r for r in rows if r["granularity"] == "projection"]
            glob = [r for r in rows if r["granularity"] == "global"]
            p80p = min(proj, key=lambda r: abs(r["p"] - 0.8))
            p80g = min(glob, key=lambda r: abs(r["p"] - 0.8))
            return (f"ppl_proj@0.8={p80p['ppl']:.1f}"
                    f";ppl_global@0.8={p80g['ppl']:.1f}"
                    f";ppl_reduction={(1 - p80p['ppl'] / p80g['ppl']) * 100:.1f}%")
        if name.startswith("table5"):
            rows = result
            uns = [r for r in rows if r["category"] == "unstructured"]
            stc = [r for r in rows if r["category"] == "structured"]
            cmp_ = [r for r in rows if r["category"] == "composite"]
            hi = max(r["p"] for r in uns)
            u = next(r for r in uns if r["p"] == hi)
            s = next(r for r in stc if r["p"] == hi)
            m = next(r for r in cmp_ if r["p"] == hi)
            return (f"latency_cut_vs_unstructured="
                    f"{(1 - m['latency_us'] / u['latency_us']) * 100:.0f}%"
                    f";ppl_vs_structured={s['ppl'] / m['ppl']:.1f}x")
        if name.startswith("fig10"):
            g = result["global"]["after"]["ppl"]
            p = result["projection"]["after"]["ppl"]
            return f"ppl_after_ft_proj={p:.1f};global={g:.1f}"
        if name.startswith("fig11"):
            rows, rows12 = result
            return f"rc_s={rows[0]['rc_s']:.1f}"
        if name.startswith("table13"):
            rows = result
            m = [r for r in rows if r["method"] == "mosaic"]
            return f"mosaic_pts={len(m)}"
        if name == "kernel_bench":
            bs, at = result
            return (f"block_skip={bs['skip_frac']:.2f}"
                    f";flash_MiB_avoided="
                    f"{at['score_matrix_mib_avoided']:.0f}")
        if name == "moe_kernel_bench":
            return (f"grouped_vs_loop={result['grouped_vs_loop']:.2f}x"
                    f";launches_per_proj="
                    f"{result['grouped_launches_per_proj']:.0f}vs"
                    f"{result['loop_launches_per_proj']:.0f}"
                    f";decode_experts="
                    f"{result['decode_ragged_experts_per_launch']:.0f}"
                    f"of{result['n_experts']}")
        if name == "paged_attn_bench":
            return (f"kv_bytes_cut={result['kv_bytes_reduction']:.2f}"
                    f";token_identical="
                    f"{bool(result['token_identical'])}"
                    f";kernel_err={result['kernel_max_err']:.1e}")
        if name == "serve_bench":
            return (f"continuous_vs_static={result['speedup']:.2f}x"
                    f";sparse_agrees={result['sparse_agrees']}"
                    f";flops_skipped={result['flops_skipped']:.2f}"
                    f";paged_concurrency="
                    f"{result['paged_concurrency_vs_contiguous']:.2f}x"
                    f";prefix_hit_rate={result['prefix_hit_rate']:.2f}"
                    f";slo_vs_fifo_attainment="
                    f"{result['slo_vs_fifo_attainment']:.2f}x")
        if name == "prune_pipeline":
            return ";".join(f"{r['arch']}={r['seconds']:.1f}s"
                            for r in result)
        if name == "recipe_sweep":
            front = [r for r in result if r["pareto"]]
            best = max(result,
                       key=lambda r: r["quality_per_byte"] or 0.0)
            return (f"points={len(result)};pareto={len(front)}"
                    f";best_qpb={best['quality_per_byte']:.3f}")
    except Exception as e:                            # noqa: BLE001
        return f"derive-error:{e!r}"
    return "-"


def _metrics(name: str, result, us: float) -> dict:
    """Flat per-row metric dict for the regression guard / trajectory
    artifact. Wall-clock metrics (``*_seconds``, ``*_per_s``) are
    recorded for the trajectory; the committed baseline gates the
    machine-independent ones (ratios, launch counts, agreement flags)."""
    m = {"us_per_call": us}
    try:
        if name == "moe_kernel_bench":
            m.update({k: result[k] for k in (
                "grouped_vs_loop", "grouped_launches_per_proj",
                "loop_launches_per_proj", "grouped_tokens_per_s",
                "loop_tokens_per_s", "dense_tokens_per_s", "n_experts",
                "max_err_vs_dense", "decode_experts_routed",
                "decode_grouped_experts_per_launch",
                "decode_ragged_experts_per_launch",
                "ragged_launches_per_proj", "decode_occupancy_match",
                "decode_empty_experts_skipped", "decode_paths_identical",
                "decode_grouped_tokens_per_s",
                "decode_ragged_tokens_per_s", "prune_seconds",
                "quant_paths_identical", "quant_bytes_ratio",
                "quant_launches_per_proj")})
        elif name == "kernel_bench":
            bs, _ = result
            m.update({"skip_frac": bs["skip_frac"],
                      "allclose_err": bs["allclose_err"],
                      "quant_identical": bs["quant_identical"],
                      "quant_bytes_ratio": bs["quant_bytes_ratio"],
                      "quant_rel_err": bs["quant_rel_err"]})
        elif name == "paged_attn_bench":
            m.update({k: result[k] for k in (
                "kernel_agrees", "kernel_max_err", "token_identical",
                "kernel_traced", "kv_bytes_reduction",
                "gather_kv_bytes_per_tick", "fused_kv_bytes_per_tick",
                "gather_tokens_per_s", "fused_tokens_per_s")})
        elif name == "serve_bench":
            m.update({"continuous_vs_static": result["speedup"],
                      "sparse_agrees": float(result["sparse_agrees"]),
                      "flops_skipped": result["flops_skipped"],
                      "paged_agrees": float(result["paged_agrees"]),
                      "paged_concurrency_vs_contiguous":
                          result["paged_concurrency_vs_contiguous"],
                      "paged_vs_contiguous_tokens":
                          result["paged_vs_contiguous_tokens"],
                      "prefix_hit_rate": result["prefix_hit_rate"],
                      "fifo_attainment": result["fifo_attainment"],
                      "slo_attainment": result["slo_attainment"],
                      "slo_vs_fifo_attainment":
                          result["slo_vs_fifo_attainment"]})
            for r in result["rows"]:
                m[f"{r['engine']}_tokens_per_s"] = r["tokens_per_s"]
            for r in result["policy_rows"]:
                m[f"{r['policy']}_queue_p99_ms"] = r["queue_p99"]
                m[f"{r['policy']}_total_p99_ms"] = r["p99"]
        elif name == "prune_pipeline":
            for r in result:
                m[f"{r['arch']}_prune_seconds"] = r["seconds"]
                m[f"{r['arch']}_flop_savings"] = r["flop_savings"]
        elif name == "recipe_sweep":
            m.update({"points": float(len(result)),
                      "pareto_points":
                          float(sum(1 for r in result if r["pareto"]))})
    except Exception as e:                            # noqa: BLE001
        m["metrics_error"] = repr(e)
    return m


if __name__ == "__main__":
    main()
