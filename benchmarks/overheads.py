"""E5 + Fig 12: end-to-end overheads and calibration-size sweep.

Fig 11: RC (profile once) + PC (per granularity) wall-clock, plus
fine-tune-to-quality time from E4's recovery-speed measurements.
Fig 12: perplexity + pruning time vs calibration sample count.
"""
from __future__ import annotations

import time


from benchmarks.common import (get_trained_model, perplexity,
                               rank_artifact)
from repro.core.prune_controller import run_pruning_controller


def run_e5():
    cfg, params, c = get_trained_model()
    t0 = time.perf_counter()
    art = rank_artifact(params, cfg, c)
    rc_seconds = time.perf_counter() - t0
    rows = []
    for g in ("global", "layer", "projection"):
        res = run_pruning_controller(params, cfg, art, 0.8,
                                     category="unstructured",
                                     granularity=g)
        rows.append({"granularity": g, "rc_s": rc_seconds,
                     "pc_s": res.prune_seconds,
                     "ppl": perplexity(res.params, res.cfg, c)})
    return rows


def run_fig12(sample_sizes=(1, 4, 16, 64)):
    cfg, params, c = get_trained_model()
    rows = []
    for n in sample_sizes:
        t0 = time.perf_counter()
        art = rank_artifact(params, cfg, c, n_samples=n)
        res = run_pruning_controller(params, cfg, art, 0.8,
                                     category="unstructured",
                                     granularity="projection")
        dt = time.perf_counter() - t0
        rows.append({"samples": n, "seconds": dt,
                     "ppl": perplexity(res.params, res.cfg, c)})
    return rows


def main(fast: bool = True):
    rows = run_e5()
    print("granularity,rc_s,pc_s,ppl")
    for r in rows:
        print(f"{r['granularity']},{r['rc_s']:.2f},{r['pc_s']:.2f},"
              f"{r['ppl']:.2f}")
    sizes = (4, 32) if fast else (1, 4, 16, 64)
    rows12 = run_fig12(sizes)
    print("\nsamples,seconds,ppl")
    for r in rows12:
        print(f"{r['samples']},{r['seconds']:.2f},{r['ppl']:.2f}")
    return rows, rows12


if __name__ == "__main__":
    main(fast=False)
