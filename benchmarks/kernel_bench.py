"""Kernel benchmarks (structural, CPU container).

interpret-mode timings do not reflect TPU performance, so for each kernel
we report (a) allclose-vs-oracle error and (b) the *derived* TPU win:
block-sparse — fraction of weight tiles skipped (= MXU/HBM work saved);
flash attention — score-matrix HBM traffic avoided; ssd_scan — state
HBM round-trips avoided vs a naive scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_trained_model, rank_artifact, time_call
from repro.core.prune_controller import run_pruning_controller
from repro.core.registry import projections
from repro.common.tree import tree_get
from repro.kernels.block_sparse.ops import (block_mask_from_weight_mask,
                                            blocksparse_matmul, plan_blocks)
from repro.kernels.block_sparse.ref import block_sparse_matmul_ref


def bench_block_sparse(p: float = 0.8, block: int = 16):
    """Block-skip fraction on a real Mosaic-pruned model + allclose.

    Uses the TPU-native block-structured mask mode (wanda_block): pruned
    tiles are exactly what the Pallas kernel skips — skip_frac ~ p."""
    cfg, params, c = get_trained_model()
    art = rank_artifact(params, cfg, c)
    res = run_pruning_controller(params, cfg, art, p,
                                 category="unstructured",
                                 selector="wanda_block")
    skipped, total = 0, 0
    for proj in projections(res.cfg):
        w = np.asarray(tree_get(res.params, proj.path))
        w2 = w.reshape(-1, w.shape[-1])
        K, N = (w2.shape[0] // block) * block, (w2.shape[1] // block) * block
        if K == 0 or N == 0:
            continue
        bm = block_mask_from_weight_mask(w2[:K, :N] != 0, block, block)
        skipped += int((~bm).sum())
        total += bm.size
    # correctness at kernel block size on a synthetic case
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512))
    w = jax.random.normal(key, (512, 384))
    mask = np.array(jax.random.uniform(key, (512, 384)) > 0.85)
    w = jnp.where(jnp.asarray(mask), w, 0)
    bm = block_mask_from_weight_mask(mask, 128, 128)
    counts, idx = plan_blocks(bm)
    y = blocksparse_matmul(x, w, counts, idx, interpret=True)
    yref = block_sparse_matmul_ref(x, w, jnp.asarray(bm), 128, 128)
    err = float(jnp.abs(y - yref).max())
    return {"skip_frac": skipped / max(total, 1), "allclose_err": err,
            "p": p, "block": block}


def bench_quant_sparse(block: int = 16, K: int = 256, N: int = 256):
    """Kept-tile int8 path vs its dequantized reference.

    Packs a block-structured synthetic weight with ``quant="int8"``,
    then checks (a) the quantized kernel is *bitwise* identical to the
    unquantized kernel over the fake-quant weight (pow2 scales commute
    with float rounding), (b) the real int8 storage — tiles + scale map
    + plan — versus a dense bf16 copy, (c) quantization error against
    the unquantized dense product."""
    from repro.serve.sparse import (dequantized_weight, pack_projection,
                                    sparse_linear)
    kx, kw, km = jax.random.split(jax.random.PRNGKey(3), 3)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    keep = jax.random.uniform(km, (K // block, N // block)) > 0.8
    mask = jnp.repeat(jnp.repeat(keep, block, 0), block, 1)
    w = jnp.where(mask, w, 0)
    p = pack_projection(w, block, quant="int8")
    wfq = jnp.asarray(dequantized_weight(p, K))
    x = jax.random.normal(kx, (64, K), jnp.float32)
    y_q = sparse_linear(x, wfq, p, interpret=True, quant="int8")
    y_ref = sparse_linear(x, wfq, p, interpret=True, quant="none")
    dense = x @ w
    tile_bytes = int(p.tiles.size)                    # int8: 1 B/elem
    scale_bytes = int(p.scales.size) * 4
    plan_bytes = (int(p.counts.size) + int(p.indices.size)
                  + int(p.slots.size)) * 4
    bytes_int8 = tile_bytes + scale_bytes + plan_bytes
    return {"quant_identical": float(jnp.array_equal(y_q, y_ref)),
            "quant_bytes_ratio": bytes_int8 / (K * N * 2),
            "quant_rel_err": float(jnp.abs(y_q - dense).max()
                                   / jnp.abs(dense).max()),
            "quant_tile_bytes": tile_bytes,
            "quant_density": p.density}


def bench_attention_paths(S: int = 4096):
    """Chunked (flash-oracle) vs dense attention: CPU latency + the memory
    the flash path avoids (the S x S score matrix)."""
    from repro.models.layers import (_chunked_causal_attention,
                                     _dense_attention)
    B, H, D = 1, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = jax.jit(lambda q, k, v: _dense_attention(q, k, v, pos, pos, True))
    chunk = jax.jit(lambda q, k, v: _chunked_causal_attention(q, k, v, pos))
    t_dense = time_call(dense, q, k, v, repeats=3)
    t_chunk = time_call(chunk, q, k, v, repeats=3)
    score_bytes = B * H * S * S * 4
    return {"dense_us": t_dense, "chunked_us": t_chunk,
            "score_matrix_mib_avoided": score_bytes / 2 ** 20}


def main(fast: bool = True):
    bs = bench_block_sparse()
    print(f"block_sparse,p={bs['p']},skip_frac={bs['skip_frac']:.3f},"
          f"err={bs['allclose_err']:.2e}")
    qs = bench_quant_sparse()
    bs.update(qs)          # quant metrics ride the block-sparse row
    print(f"quant_sparse,identical={bool(qs['quant_identical'])},"
          f"bytes_ratio={qs['quant_bytes_ratio']:.3f},"
          f"rel_err={qs['quant_rel_err']:.2e}")
    at = bench_attention_paths(2048 if fast else 4096)
    print(f"attention,dense_us={at['dense_us']:.0f},"
          f"chunked_us={at['chunked_us']:.0f},"
          f"score_MiB_avoided={at['score_matrix_mib_avoided']:.0f}")
    return bs, at


if __name__ == "__main__":
    main(fast=False)
