"""Benchmark-regression guard: compare a ``run.py --json`` metrics file
against the committed ``benchmarks/baseline.json``.

    python benchmarks/regression.py BENCH_PR.json [baseline.json]

The baseline names the metrics it gates, one of three ways per metric:

- ``{"ref": v}``   — value must stay within ±``tolerance`` (relative,
  default 20%) of ``v``: the regression band for ratios/fractions that
  are stable across machines (tile-skip fractions, FLOP savings).
- ``{"min": v}`` / ``{"max": v}`` — hard floor/ceiling, no band: the
  acceptance criteria (grouped kernel >= 1.2x the per-expert loop, one
  launch per projection, sparse==dense agreement).

Wall-clock metrics (``*_seconds``, ``*_tokens_per_s``) ride along in
BENCH_PR.json as the per-PR trajectory artifact but are NOT gated —
shared CI runners vary far beyond any honest tolerance. A gated metric
missing from the metrics file fails loudly (a silently dropped
benchmark row is itself a regression).
"""
from __future__ import annotations

import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def check(metrics: dict, baseline: dict) -> list:
    """Returns a list of failure strings (empty = pass)."""
    tol = float(baseline.get("tolerance", 0.20))
    rows = metrics.get("rows", {})
    failures = []
    for key, rule in baseline["metrics"].items():
        row, _, metric = key.partition(".")
        have = rows.get(row, {})
        if metric not in have:
            failures.append(f"{key}: missing from metrics file "
                            f"(row keys: {sorted(have) or 'none'})")
            continue
        v = float(have[metric])
        if "ref" in rule:
            ref = float(rule["ref"])
            lo, hi = ref * (1 - tol), ref * (1 + tol)
            if not lo <= v <= hi:
                failures.append(f"{key}: {v:.4g} outside ±{tol:.0%} of "
                                f"baseline {ref:.4g} [{lo:.4g}, {hi:.4g}]")
        if "min" in rule and v < float(rule["min"]):
            failures.append(f"{key}: {v:.4g} below floor {rule['min']:.4g}")
        if "max" in rule and v > float(rule["max"]):
            failures.append(f"{key}: {v:.4g} above ceiling "
                            f"{rule['max']:.4g}")
    return failures


def main(argv) -> int:
    if not argv:
        print(__doc__)
        return 2
    with open(argv[0]) as f:
        metrics = json.load(f)
    baseline_path = argv[1] if len(argv) > 1 else DEFAULT_BASELINE
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = check(metrics, baseline)
    n = len(baseline["metrics"])
    if failures:
        print(f"benchmark regression: {len(failures)}/{n} gated metrics "
              f"failed vs {os.path.basename(baseline_path)}")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    print(f"benchmark regression guard: {n} gated metrics within bounds "
          f"vs {os.path.basename(baseline_path)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
