"""Fault-tolerance / elasticity demo: train, checkpoint, simulate a
preemption, restart on a *different* device mesh (fleet shrank/grew), and
continue — losses line up across the restart.

  PYTHONPATH=src python examples/elastic_restart.py
(re-executes itself with 8 fake devices)
"""
import os
import subprocess
import sys

BODY = r"""
import os, json, tempfile
import jax, jax.numpy as jnp
from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import SyntheticCorpus
from repro.distributed.elastic import make_elastic_mesh, reshard_state
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer

cfg = get_smoke_config("llama3-8b", d_model=128, d_ff=384, vocab=512,
                       n_periods=2).replace(scan_layers=False)
corpus = SyntheticCorpus(cfg.vocab, seed=0)
opt = OptConfig(lr=2e-3, warmup_steps=10, total_steps=100)
d = tempfile.mkdtemp()
ckpt = CheckpointManager(d, keep=2)

# phase 1: train 30 steps, checkpoint, then 'preemption'
t1 = Trainer(cfg, opt, corpus.batches(16, 64), ckpt=ckpt, ckpt_every=10,
             compute_dtype=jnp.float32, prefetch=False)
r1 = t1.run(30)
t1.preemption.trigger()
r1b = t1.run(10)          # exits immediately with a final checkpoint
print(f"phase1: {r1.steps_run} steps, preempted={r1b.preempted}, "
      f"ckpts={ckpt.all_steps()}")

# phase 2: 'new fleet' — restore onto an elastic mesh and continue
mesh = make_elastic_mesh(8, target_tp=2)
t2 = Trainer(cfg, opt, corpus.batches(16, 64, start=t1.step), ckpt=ckpt,
             ckpt_every=10, compute_dtype=jnp.float32, prefetch=False)
t2.state = reshard_state(t2.state, mesh, cfg)
r2 = t2.run(20)
print(f"phase2 on mesh {dict(mesh.shape)}: resumed at {t2.step - 20}, "
      f"losses {r2.losses[0]:.3f} -> {r2.losses[-1]:.3f}")
assert r2.losses[0] < r1.losses[0], "restart lost progress!"
print("ELASTIC RESTART OK")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", BODY], env=env)
    raise SystemExit(out.returncode)


if __name__ == "__main__":
    main()
