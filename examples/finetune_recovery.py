"""E4 live: LoRA recovery of an 80%-pruned model (Fig 10 analogue).

  PYTHONPATH=src python examples/finetune_recovery.py
"""
import math

import jax
import jax.numpy as jnp

from repro.core.lora import init_lora, merge_lora
from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.configs.registry import get_smoke_config
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, apply_updates, init_opt
from repro.train.trainer import Trainer


def main():
    cfg = get_smoke_config("llama3-8b", d_model=128, d_ff=384, vocab=512,
                           n_periods=4).replace(scan_layers=False)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    trainer = Trainer(cfg, OptConfig(lr=2e-3, warmup_steps=20,
                                     total_steps=200),
                      corpus.batches(32, 64), compute_dtype=jnp.float32,
                      prefetch=False)
    trainer.run(200)
    params = trainer.state["params"]
    art = run_ranking_controller(params, cfg,
                                 corpus.calibration_batches(16, 8, 64))
    res = run_pruning_controller(params, cfg, art, 0.8,
                                 category="unstructured")

    def ppl(p_, c_):
        tot = 0.0
        for tok, lab in corpus.batches(8, 64, start=900, n=4):
            lo, _, _ = T.forward(p_, c_, tok, compute_dtype=jnp.float32)
            tot += float(T.cross_entropy(lo, lab, c_.vocab))
        return math.exp(tot / 4)

    print(f"dense ppl {ppl(params, cfg):.1f}; "
          f"80%-pruned ppl {ppl(res.params, res.cfg):.1f}")

    rank = 8
    adapters = init_lora(jax.random.PRNGKey(1), res.params, res.cfg, rank)

    def loss(ad, tok, lab):
        merged = merge_lora(res.params, res.cfg, ad, rank=rank)
        l, _ = T.loss_fn(merged, res.cfg, tok, lab,
                         compute_dtype=jnp.float32)
        return l

    ocfg = OptConfig(lr=5e-3, warmup_steps=5, total_steps=100,
                     weight_decay=0.0)
    ostate = init_opt(adapters, ocfg)
    gfn = jax.jit(jax.value_and_grad(loss))
    for i, (tok, lab) in enumerate(corpus.batches(16, 64, start=300, n=100)):
        l, g = gfn(adapters, tok, lab)
        adapters, ostate, _ = apply_updates(adapters, g, ostate, ocfg)
        if i % 20 == 0:
            print(f"lora step {i:3d} loss {float(l):.3f}")
    merged = merge_lora(res.params, res.cfg, adapters, rank=rank)
    print(f"recovered ppl {ppl(merged, res.cfg):.1f}")


if __name__ == "__main__":
    main()
