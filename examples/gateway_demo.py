"""Streaming front-door demo: start the HTTP gateway on a saved pruned
artifact, stream two concurrent requests that share one system prompt
(`prefix_id`) plus a follow-up that maps the registered prefix blocks,
assert the streamed tokens are identical to driving the engine
directly, and dump the `/metrics` JSON.

  PYTHONPATH=src python -m repro.launch.prune --smoke \
      --recipe recipes/golden-smoke.json --out pruned-artifact
  PYTHONPATH=src python examples/gateway_demo.py \
      --artifact pruned-artifact --out gateway-metrics.json

This is also CI's ``gateway-smoke`` acceptance check: the token-
identity assertion here is the gateway's core contract — the asyncio
front door, background engine thread, and per-request channels must
add zero divergence over ``ContinuousEngine.run``.
"""
from __future__ import annotations

import argparse
import asyncio
import json

import jax.numpy as jnp

from repro.core.artifact import PrunedArtifact
from repro.serve.batching import ContinuousEngine
from repro.serve.config import ServeConfig
from repro.serve.gateway import Gateway
from repro.serve.scheduler import Request


async def stream_generate(port: int, body: dict) -> list:
    """POST /generate over a raw socket; returns the ndjson events."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode()
    writer.write(b"POST /generate HTTP/1.1\r\nHost: demo\r\n"
                 b"Content-Length: %d\r\n\r\n" % len(payload) + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    await writer.wait_closed()
    return [json.loads(line) for line in
            data.partition(b"\r\n\r\n")[2].splitlines() if line.strip()]


async def fetch(port: int, path: str) -> dict:
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: demo\r\n\r\n".encode())
    await writer.drain()
    data = await reader.read()
    writer.close()
    return json.loads(data.partition(b"\r\n\r\n")[2])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", required=True,
                    help="PrunedArtifact bundle directory")
    ap.add_argument("--out", default="gateway-metrics.json",
                    help="where to dump the /metrics JSON")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    artifact = PrunedArtifact.load(args.artifact)
    serve_cfg = ServeConfig(max_slots=3, max_seq=96, block_size=16,
                            prefill_chunk=16, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32)
    prefix = list(range(1, 33))             # the shared system prompt
    tails = [[40 + i, 50 + i, 60 + i] for i in range(3)]

    # ---- reference: the same requests driven through the engine
    # directly (fresh engine, same config -> same jitted steps)
    direct_eng = ContinuousEngine.from_artifact(artifact, serve_cfg)
    fin, _ = direct_eng.run(
        [Request(uid=i, prompt=prefix + t,
                 max_new_tokens=args.new_tokens, prefix_id="system")
         for i, t in enumerate(tails)])
    direct = {f.request.uid: f.tokens for f in fin}

    async def run_gateway() -> dict:
        eng = ContinuousEngine.from_artifact(artifact, serve_cfg)
        gw = await Gateway(eng, port=args.port).start()
        print(f"gateway on 127.0.0.1:{gw.port}: two concurrent "
              f"requests, then a follow-up that hits the shared prefix")
        health = await fetch(gw.port, "/healthz")
        assert health == {"status": "ok"}, health
        streams = list(await asyncio.gather(*[
            stream_generate(gw.port, {
                "tokens": prefix + t, "max_new_tokens": args.new_tokens,
                "prefix_id": "system"}) for t in tails[:2]]))
        # the concurrent pair registered the system prompt's KV blocks
        # on prefill completion; this one maps them instead of
        # prefilling (greedy tokens are unaffected either way)
        streams.append(await stream_generate(gw.port, {
            "tokens": prefix + tails[2],
            "max_new_tokens": args.new_tokens, "prefix_id": "system"}))
        metrics = await fetch(gw.port, "/metrics")
        _, stats = await gw.close()
        for events in streams:
            done = [e for e in events if e["event"] == "done"][0]
            toks = [e["token"] for e in events if e["event"] == "token"]
            assert toks == done["tokens"], "stream != terminal event"
            assert toks == direct[done["uid"]], (
                f"uid {done['uid']}: gateway {toks} != "
                f"direct {direct[done['uid']]}")
            print(f"  uid {done['uid']}: {len(toks)} tokens, "
                  f"{done['prompt_blocks_shared']} prefix blocks shared, "
                  f"{done['metrics']['total_ms']:.0f}ms total")
        followup = [e for e in streams[2] if e["event"] == "done"][0]
        assert followup["prompt_blocks_shared"] > 0, \
            "follow-up request missed the prefix cache"
        assert stats.generated_tokens == sum(len(t) for t in direct.values())
        return metrics

    metrics = asyncio.run(run_gateway())
    with open(args.out, "w") as f:
        json.dump(metrics, f, indent=2)
    stages = metrics["metrics"]["series"]["request.total_ms"]
    print("token-identity vs direct engine: OK")
    print(f"/metrics -> {args.out}: total_ms p50={stages['p50']:.0f} "
          f"p99={stages['p99']:.0f} over {stages['count']} requests")


if __name__ == "__main__":
    main()
