"""Quickstart: train a small LM, Mosaic-prune it, compare, generate.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main():
    # 1. a small llama-3-family model + synthetic corpus
    cfg = get_smoke_config("llama3-8b", d_model=128, d_ff=384,
                           vocab=512, n_periods=4)
    cfg = cfg.replace(scan_layers=False)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)

    # 2. train briefly
    opt = OptConfig(lr=2e-3, warmup_steps=20, total_steps=200)
    trainer = Trainer(cfg, opt, corpus.batches(32, 64),
                      compute_dtype=jnp.float32, prefetch=False)
    report = trainer.run(200)
    params = trainer.state["params"]
    print(f"trained 200 steps: loss {report.losses[0]:.2f} -> "
          f"{report.losses[-1]:.2f}")

    # 3. Mosaic: rank once (RC), prune composite at 50% (PC)
    calib = corpus.calibration_batches(16, 8, 64)
    art = run_ranking_controller(params, cfg, calib)
    res = run_pruning_controller(params, cfg, art, 0.5,
                                 category="composite", align_channels=8)
    from repro.common.tree import param_count
    print(f"composite pruning: {param_count(params)} -> "
          f"{param_count(res.params)} params "
          f"(unstructured sparsity "
          f"{res.info['unstructured_sparsity']:.0%})")

    # 4. perplexity before/after
    import math
    def ppl(p_, c_):
        tot = 0.0
        for tok, lab in corpus.batches(8, 64, start=900, n=4):
            lo, _, _ = T.forward(p_, c_, tok, compute_dtype=jnp.float32)
            tot += float(T.cross_entropy(lo, lab, c_.vocab))
        return math.exp(tot / 4)
    print(f"ppl dense {ppl(params, cfg):.1f} -> "
          f"pruned {ppl(res.params, res.cfg):.1f}")

    # 5. generate with the pruned SLM
    eng = Engine(res.params, res.cfg,
                 ServeConfig(max_seq=48, compute_dtype=jnp.float32,
                             cache_dtype=jnp.float32))
    prompt = jnp.asarray(corpus.batch(999, 2, 16)[:, :16])
    out = eng.generate(prompt, n_new=16)
    print("generated:", out[0, 16:].tolist())


if __name__ == "__main__":
    main()
