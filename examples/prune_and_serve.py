"""Serve-path comparison across the three Mosaic pruning categories:
model size, CPU forward latency, perplexity — the E3 tradeoff, live —
then the full declarative loop: one PruneRecipe runs the pipeline, the
PrunedArtifact round-trips through disk, and the continuous-batching
engine serves it with the *saved* block plans (no pack_model at serve
startup).

  PYTHONPATH=src python examples/prune_and_serve.py
"""
import math
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import param_bytes, param_count
from repro.configs.registry import get_smoke_config
from repro.core.artifact import PrunedArtifact
from repro.core.pipeline import MosaicPipeline
from repro.core.rank_controller import profile_model
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.serve.batching import ContinuousEngine, latency_percentiles
from repro.serve.config import ServeConfig
from repro.serve.scheduler import Request
from repro.serve.sparse import flop_savings
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main():
    cfg = get_smoke_config("llama3-8b", d_model=128, d_ff=384, vocab=512,
                           n_periods=4).replace(scan_layers=False)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    trainer = Trainer(cfg, OptConfig(lr=2e-3, warmup_steps=20,
                                     total_steps=200),
                      corpus.batches(32, 64), compute_dtype=jnp.float32,
                      prefetch=False)
    trainer.run(200)
    params = trainer.state["params"]
    # one RC profile serves every category below (the paper's E5 win)
    art = profile_model(params, cfg, corpus.calibration_batches(16, 8, 64))
    tokens, labels = next(corpus.batches(8, 64, start=900))

    def profile(p_, c_, name):
        f = jax.jit(lambda pr, t: T.forward(pr, c_, t,
                                            compute_dtype=jnp.float32)[0])
        f(p_, tokens)                       # compile
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(p_, tokens))
        lat = (time.perf_counter() - t0) / 5 * 1e3
        lo, _, _ = T.forward(p_, c_, tokens, compute_dtype=jnp.float32)
        ppl = math.exp(float(T.cross_entropy(lo, labels, c_.vocab)))
        print(f"{name:14s} params={param_count(p_):9d} "
              f"bytes={param_bytes(p_):10d} latency={lat:7.1f}ms "
              f"ppl={ppl:8.1f}")

    profile(params, cfg, "dense")
    base = PruneRecipe(arch=cfg.name, p=0.6, selector="wanda_block",
                       align_channels=16, block=16,
                       calibration=CalibrationSpec(16, 8, 64))
    artifacts = {}
    for cat in ("unstructured", "composite", "structured"):
        recipe = base.replace(category=cat)
        bundle = MosaicPipeline(recipe).run(params, cfg, rank_artifact=art)
        profile(bundle.params, bundle.cfg, cat)
        artifacts[cat] = bundle

    # the composite artifact round-trips through disk, then serves with
    # its saved plans — exactly what launch/serve.py --artifact does
    with tempfile.TemporaryDirectory() as d:
        artifacts["composite"].save(d)
        loaded = PrunedArtifact.load(d)
        pk = loaded.report["pack"]
        print(f"\nserving saved composite artifact: {pk['n_packed']} plans "
              f"({pk['n_skipped']} projections skipped at pack), "
              f"{flop_savings(loaded.packed):.0%} FLOPs skipped")
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=corpus.batch(i, 1, s0)[0, :s0].tolist(),
                        max_new_tokens=16)
                for i, s0 in enumerate(rng.integers(8, 33, size=8).tolist())]
        serve_cfg = ServeConfig(max_slots=4, max_seq=64, block_size=16,
                                compute_dtype=jnp.float32,
                                cache_dtype=jnp.float32)
        eng = ContinuousEngine.from_artifact(loaded, serve_cfg)
        finished, stats = eng.run(reqs)
    lat = latency_percentiles(finished)
    print(f"continuous+sparse: {stats.generated_tokens} tokens in "
          f"{stats.wall_s:.2f}s ({stats.tokens_per_s:.1f} tok/s incl. "
          f"compile), slot util {stats.slot_utilization:.0%}, "
          f"p50 {lat['p50']:.0f}ms p99 {lat['p99']:.0f}ms")


if __name__ == "__main__":
    main()
