"""Serve-path comparison across the three Mosaic pruning categories:
model size, CPU forward latency, perplexity — the E3 tradeoff, live —
then the pruned model served end-to-end through the continuous-batching
engine with the block-sparse fast path.

  PYTHONPATH=src python examples/prune_and_serve.py
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.common.tree import param_bytes, param_count
from repro.data.pipeline import SyntheticCorpus
from repro.configs.registry import get_smoke_config
from repro.models import transformer as T
from repro.serve.batching import ContinuousEngine, latency_percentiles
from repro.serve.scheduler import Request
from repro.serve.sparse import flop_savings, pack_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main():
    cfg = get_smoke_config("llama3-8b", d_model=128, d_ff=384, vocab=512,
                           n_periods=4).replace(scan_layers=False)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    trainer = Trainer(cfg, OptConfig(lr=2e-3, warmup_steps=20,
                                     total_steps=200),
                      corpus.batches(32, 64), compute_dtype=jnp.float32,
                      prefetch=False)
    trainer.run(200)
    params = trainer.state["params"]
    art = run_ranking_controller(params, cfg,
                                 corpus.calibration_batches(16, 8, 64))
    tokens, labels = next(corpus.batches(8, 64, start=900))

    def profile(p_, c_, name):
        f = jax.jit(lambda pr, t: T.forward(pr, c_, t,
                                            compute_dtype=jnp.float32)[0])
        f(p_, tokens)                       # compile
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(p_, tokens))
        lat = (time.perf_counter() - t0) / 5 * 1e3
        lo, _, _ = T.forward(p_, c_, tokens, compute_dtype=jnp.float32)
        ppl = math.exp(float(T.cross_entropy(lo, labels, c_.vocab)))
        print(f"{name:14s} params={param_count(p_):9d} "
              f"bytes={param_bytes(p_):10d} latency={lat:7.1f}ms "
              f"ppl={ppl:8.1f}")

    profile(params, cfg, "dense")
    results = {}
    for cat in ("unstructured", "composite", "structured"):
        res = run_pruning_controller(params, cfg, art, 0.6, category=cat,
                                     align_channels=8)
        profile(res.params, res.cfg, cat)
        results[cat] = res

    # serve the composite-pruned model through the continuous engine,
    # MLPs routed through the block-sparse kernel (interpret on CPU)
    res = results["composite"]
    packed = pack_model(res.params, res.cfg, block=16)
    print(f"\nserving composite-pruned model: {len(packed)} packed "
          f"projections, {flop_savings(packed):.0%} FLOPs skipped")
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=corpus.batch(i, 1, s0)[0, :s0].tolist(),
                    max_new_tokens=16)
            for i, s0 in enumerate(rng.integers(8, 33, size=8).tolist())]
    eng = ContinuousEngine(res.params, res.cfg, max_slots=4, max_seq=64,
                           compute_dtype=jnp.float32,
                           cache_dtype=jnp.float32, packed=packed)
    finished, stats = eng.run(reqs)
    lat = latency_percentiles(finished)
    print(f"continuous+sparse: {stats.generated_tokens} tokens in "
          f"{stats.wall_s:.2f}s ({stats.tokens_per_s:.1f} tok/s incl. "
          f"compile), slot util {stats.slot_utilization:.0%}, "
          f"p50 {lat['p50']:.0f}ms p99 {lat['p99']:.0f}ms")


if __name__ == "__main__":
    main()
