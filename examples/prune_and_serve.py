"""Serve-path comparison across the three Mosaic pruning categories:
model size, CPU forward latency, perplexity — the E3 tradeoff, live.

  PYTHONPATH=src python examples/prune_and_serve.py
"""
import functools
import math
import time

import jax
import jax.numpy as jnp

from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.common.tree import param_bytes, param_count
from repro.data.pipeline import SyntheticCorpus
from repro.configs.registry import get_smoke_config
from repro.models import transformer as T
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main():
    cfg = get_smoke_config("llama3-8b", d_model=128, d_ff=384, vocab=512,
                           n_periods=4).replace(scan_layers=False)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    trainer = Trainer(cfg, OptConfig(lr=2e-3, warmup_steps=20,
                                     total_steps=200),
                      corpus.batches(32, 64), compute_dtype=jnp.float32,
                      prefetch=False)
    trainer.run(200)
    params = trainer.state["params"]
    art = run_ranking_controller(params, cfg,
                                 corpus.calibration_batches(16, 8, 64))
    tokens, labels = next(corpus.batches(8, 64, start=900))

    def profile(p_, c_, name):
        f = jax.jit(lambda pr, t: T.forward(pr, c_, t,
                                            compute_dtype=jnp.float32)[0])
        f(p_, tokens)                       # compile
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(p_, tokens))
        lat = (time.perf_counter() - t0) / 5 * 1e3
        lo, _, _ = T.forward(p_, c_, tokens, compute_dtype=jnp.float32)
        ppl = math.exp(float(T.cross_entropy(lo, labels, c_.vocab)))
        print(f"{name:14s} params={param_count(p_):9d} "
              f"bytes={param_bytes(p_):10d} latency={lat:7.1f}ms "
              f"ppl={ppl:8.1f}")

    profile(params, cfg, "dense")
    for cat in ("unstructured", "composite", "structured"):
        res = run_pruning_controller(params, cfg, art, 0.6, category=cat,
                                     align_channels=8)
        profile(res.params, res.cfg, cat)


if __name__ == "__main__":
    main()
