"""Compiled-artifact analysis: cost terms, memory, collective bytes.

collective_bytes is not in cost_analysis — we parse the optimised HLO and
sum result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Ops inside `while` bodies (scan over
layers) execute n_periods times but appear once in the text, so callers
use the two-point period extrapolation (compile with P=1 and P=2 periods;
per-period cost = c2 - c1; total = c1 + (P-1)(c2-c1)).
"""
from __future__ import annotations

import re


DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_OP_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum of result bytes per collective kind (…-done ops skipped so
    async pairs are not double-counted)."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _TUPLE_RE.search(line)
        if m:
            inner, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(inner):
                out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> dict:
    """Per-device memory from the compiled executable.

    peak_memory_in_bytes is XLA's liveness-aware peak (the fit criterion);
    argument/temp sizes are also recorded — the CPU backend's buffer
    assignment is conservative vs the TPU memory-minimising scheduler, so
    temp is an upper bound.
    """
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "peak_memory_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = int(getattr(ma, k, 0) or 0)
    # state (args, aliased in-place) + conservative temps
    out["per_device_total"] = (out["argument_size_in_bytes"]
                               + out["temp_size_in_bytes"])
    return out


def extrapolate(c1: dict, c2: dict, n_periods: int) -> dict:
    """Two-point extrapolation over scan periods (see module docstring)."""
    out = {}
    for k in c1:
        if isinstance(c1[k], dict):
            out[k] = extrapolate(c1[k], c2[k], n_periods)
        else:
            per = c2[k] - c1[k]
            out[k] = c1[k] + (n_periods - 1) * per
    return out


# ------------------------------------------------------------ hardware

TPU_V5E = {
    "name": "tpu-v5e",
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link
    "hbm_bytes": 16 * 1024 ** 3,
}


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, n_chips: int,
                   hw: dict = TPU_V5E) -> dict:
    """The three §Roofline terms, in seconds. cost_analysis numbers are
    per-device under SPMD, so chip counts divide only the collective term
    (flops/bytes already are per-chip)."""
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = bytes_accessed / hw["hbm_bw"]
    collective_s = coll_bytes / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1)
    return terms
