"""Serving launcher: static or continuous batching, dense / pruned /
artifact-driven.

  # serve a saved PrunedArtifact: params, config, and block plans load
  # straight from disk — no ranking, pruning, or pack_model at startup
  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --artifact results/pruned_gemma --engine continuous --sparse

  # or run a recipe end-to-end (prune now, then serve the result)
  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --recipe recipes/golden-smoke.json --engine continuous --sparse

  # legacy flags still work (assembled into a recipe internally)
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --prune 0.5 --category composite --engine continuous --sparse

``--engine static`` runs the fixed-batch ``Engine``; ``--engine
continuous`` runs the slot-pool ``ContinuousEngine``. ``--sparse``
routes the serving MLPs through the Pallas block-sparse kernel using
the artifact's saved ``PackedProjection`` plans.

``--block-size N`` switches the continuous engine to the paged KV pool
(``--n-blocks`` sizes the arena, ``--prefill-chunk`` interleaves long
prompt prefills with decode); ``--shared-prefix`` demos prefix sharing
by giving every request one common system prompt under a shared
``prefix_id``:

  PYTHONPATH=src python -m repro.launch.serve --smoke --engine \
      continuous --block-size 16 --prefill-chunk 16 --shared-prefix

``--gateway`` starts the HTTP front door instead of running a canned
workload: ``POST /generate`` streams ndjson tokens, ``GET /metrics`` /
``GET /healthz`` expose the engine's observability (see
``docs/serving.md``). ``--scheduler`` picks the admission policy
(fifo | priority | slo) and ``--memory-budget`` sizes the slot/block
pools from the artifact's ``report.json`` instead of ``--max-slots``:

  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --artifact results/pruned --sparse --gateway --port 8080 \
      --block-size 16 --scheduler slo
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.artifact import PrunedArtifact
from repro.core.pipeline import MosaicPipeline
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.serve.batching import ContinuousEngine, latency_percentiles
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine
from repro.serve.scheduler import Request


def _load_or_prune(args) -> tuple:
    """Returns (params, cfg, packed, label)."""
    if args.artifact:
        art = PrunedArtifact.load(args.artifact)
        print(f"loaded artifact {args.artifact}: arch={art.recipe.arch} "
              f"category={art.report.get('category')} "
              f"{len(art.packed)} saved plans")
        return art.params, art.cfg, (art.packed if args.sparse else None), \
            "artifact"

    if args.recipe or args.prune > 0:
        if args.recipe:
            recipe = PruneRecipe.load(args.recipe)
        else:
            recipe = PruneRecipe(
                arch=args.arch, p=args.prune, category=args.category,
                align_channels=8, block=args.sparse_block,
                quant=("int8" if args.quant == "int8" else "none"),
                calibration=CalibrationSpec(n_samples=8, batch_size=4,
                                            seq_len=args.prompt_len))
        if not (args.sparse or args.save_artifact):
            # plans would be discarded — skip the pack stage entirely
            recipe = recipe.replace(stages=tuple(
                s for s in recipe.stages if s != "pack"))
        elif args.sparse and "pack" not in recipe.stages:
            # --sparse needs plans even if the recipe's stages omit pack;
            # insert before 'report' so pack coverage lands in the report
            stages = list(recipe.stages)
            at = stages.index("report") if "report" in stages else len(stages)
            recipe = recipe.replace(stages=tuple(
                stages[:at] + ["pack"] + stages[at:]))
        cfg = (get_smoke_config(recipe.arch) if args.smoke
               else get_config(recipe.arch))
        cfg = cfg.replace(scan_layers=False)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        art = MosaicPipeline(recipe).run(params, cfg)
        if args.save_artifact:
            art.save(args.save_artifact)
            print(f"saved PrunedArtifact to {args.save_artifact}")
        print(f"pruned p={recipe.p:.0%} via "
              f"{art.report.get('category') or recipe.category or 'auto'} "
              f"in {art.report.get('pipeline_seconds', 0.0):.1f}s")
        return art.params, art.cfg, (art.packed if args.sparse else None), \
            "recipe"

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    packed = None
    if args.sparse:
        # unpruned weights have no zero tiles, but the kernel path is
        # still exercised (plans at ~100% density)
        from repro.serve.sparse import pack_model
        packed = pack_model(params, cfg, block=args.sparse_block) or None
    return params, cfg, packed, "dense"


def _run_gateway(args, params, cfg, packed) -> None:
    """Serve the HTTP front door until interrupted."""
    import asyncio
    import dataclasses

    from repro.serve.gateway import Gateway, plan_placement

    group = False if args.no_group_experts else None
    ragged = True if args.ragged_moe else None
    max_seq = args.prompt_len + args.new_tokens
    if args.block_size:
        max_seq = -(-max_seq // args.block_size) * args.block_size
    if args.memory_budget:
        if not args.artifact:
            raise SystemExit("--memory-budget sizes pools from a saved "
                             "bundle's report.json; pass --artifact")
        place = plan_placement(args.artifact, args.memory_budget,
                               max_seq=max_seq, block_size=args.block_size,
                               cache_dtype=jnp.float32,
                               scheduler=args.scheduler,
                               prefill_chunk=args.prefill_chunk)
        serve_cfg = dataclasses.replace(place.serve,
                                        compute_dtype=jnp.float32,
                                        group_experts=group,
                                        ragged_moe=ragged,
                                        quant=args.quant,
                                        paged_kernel=args.paged_kernel)
        print(f"placement: weights {place.weights_bytes} B "
              f"(density {place.density:.0%}), KV "
              f"{place.kv_token_bytes} B/token -> {place.kv_tokens} "
              f"tokens, max_slots={serve_cfg.max_slots}"
              + (f", n_blocks={serve_cfg.n_blocks}"
                 if serve_cfg.paged else ""))
    else:
        serve_cfg = ServeConfig(max_slots=args.max_slots, max_seq=max_seq,
                                block_size=args.block_size,
                                n_blocks=args.n_blocks,
                                prefill_chunk=args.prefill_chunk,
                                compute_dtype=jnp.float32,
                                cache_dtype=jnp.float32,
                                group_experts=group,
                                ragged_moe=ragged,
                                quant=args.quant,
                                paged_kernel=args.paged_kernel,
                                scheduler=args.scheduler)
    eng = ContinuousEngine(params, cfg, serve_cfg, packed=packed)

    async def _serve():
        gw = await Gateway(eng, host=args.host, port=args.port,
                           temperature=args.temperature).start()
        print(f"gateway listening on http://{args.host}:{gw.port} "
              f"(scheduler={serve_cfg.scheduler}, "
              f"{'paged' if serve_cfg.paged else 'contiguous'} pool)")
        try:
            await gw.serve_forever()
        finally:
            _, stats = await gw.close()
            print(f"gateway stopped: {stats.generated_tokens} tokens, "
                  f"{stats.rejected} rejected {stats.reject_reasons}")

    try:
        asyncio.run(_serve())
    except KeyboardInterrupt:
        pass


def main() -> None:
    # surface INFO logs (e.g. pack_model's skipped-projection summary)
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="serve a saved PrunedArtifact bundle")
    ap.add_argument("--recipe", default=None, metavar="JSON",
                    help="run a PruneRecipe end-to-end, then serve it")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="with --recipe/--prune: save the bundle here")
    ap.add_argument("--engine", choices=["static", "continuous"],
                    default="static")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / number of requests")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--prune", type=float, default=0.0)
    ap.add_argument("--category", default="composite",
                    choices=["unstructured", "structured", "composite"])
    ap.add_argument("--sparse", action="store_true",
                    help="serve pruned MLPs through the block-sparse kernel")
    ap.add_argument("--sparse-block", type=int, default=16)
    ap.add_argument("--no-group-experts", action="store_true",
                    help="fall back to one block-sparse launch per MoE "
                         "expert instead of the grouped one-launch kernel")
    ap.add_argument("--ragged-moe", action="store_true",
                    help="MoE decode ticks: pack only routed tokens into "
                         "ragged expert batches (skips empty experts) "
                         "instead of full capacity-slot batches")
    ap.add_argument("--quant", choices=["int8", "none"], default=None,
                    help="projection weight storage: int8 streams the "
                         "plans' kept-tile int8 storage (needs a bundle "
                         "packed with quant), none forces the "
                         "dequantized reference path (default: follow "
                         "plan flags)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=None, metavar="N",
                    help="continuous engine: page the KV cache into "
                         "N-token blocks (default: contiguous slots)")
    ap.add_argument("--n-blocks", type=int, default=None, metavar="K",
                    help="paged: arena size in blocks (default: enough "
                         "for max_slots full sequences)")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="C",
                    help="paged: split prompt prefill into C-token "
                         "chunks interleaved with decode ticks")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="paged: decode through the fused Pallas "
                         "paged-attention kernel instead of gathering "
                         "each slot's logical KV view")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="paged demo: prepend one shared system prompt "
                         "to every request under a common prefix_id")
    ap.add_argument("--gateway", action="store_true",
                    help="start the streaming HTTP front door instead "
                         "of running a canned workload")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="gateway port (0 = ephemeral, printed at start)")
    ap.add_argument("--scheduler", default="fifo",
                    help="admission policy: fifo | priority | slo")
    ap.add_argument("--memory-budget", type=int, default=None,
                    metavar="BYTES",
                    help="with --artifact: size max_slots/n_blocks from "
                         "the bundle's report.json for this budget "
                         "(overrides --max-slots/--n-blocks)")
    args = ap.parse_args()

    params, cfg, packed, source = _load_or_prune(args)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    if packed:
        from repro.serve.sparse import flop_savings
        print(f"sparse fast path: {len(packed)} plans "
              f"({source}), {flop_savings(packed):.0%} projection "
              f"FLOPs skipped")

    if args.gateway:
        _run_gateway(args, params, cfg, packed)
        return

    max_seq = args.prompt_len + args.new_tokens
    group = False if args.no_group_experts else None
    ragged = True if args.ragged_moe else None
    if args.engine == "static":
        if args.block_size:
            print("note: --block-size is a continuous-engine flag; "
                  "the static engine always uses a contiguous cache")
        serve_cfg = ServeConfig(max_seq=max_seq,
                                compute_dtype=jnp.float32,
                                cache_dtype=jnp.float32,
                                group_experts=group,
                                ragged_moe=ragged,
                                quant=args.quant)
        eng = Engine(params, cfg, serve_cfg, packed=packed)
        prompt = jnp.asarray(
            corpus.batch(0, args.batch, args.prompt_len)[:, :args.prompt_len])
        t0 = time.perf_counter()
        out = eng.generate(prompt, args.new_tokens,
                           temperature=args.temperature)
        dt = time.perf_counter() - t0
        toks = args.batch * args.new_tokens
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s incl. compile)")
        print("sample:", out[0, -args.new_tokens:].tolist()[:16], "...")
        return

    # continuous: mixed-length requests through the slot / block pool
    rng = np.random.default_rng(0)
    shared = (corpus.batch(99, 1, args.prompt_len)[0].tolist()
              if args.shared_prefix else [])
    reqs = []
    for i in range(args.batch):
        s0 = int(rng.integers(max(args.prompt_len // 2, 1),
                              args.prompt_len + 1))
        prompt = shared + corpus.batch(i, 1, s0)[0, :s0].tolist()
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=args.new_tokens,
                            prefix_id="system" if shared else None))
    max_seq = max(len(r.prompt) for r in reqs) + args.new_tokens
    if args.block_size:
        max_seq = -(-max_seq // args.block_size) * args.block_size
    serve_cfg = ServeConfig(max_slots=args.max_slots, max_seq=max_seq,
                            block_size=args.block_size,
                            n_blocks=args.n_blocks,
                            prefill_chunk=args.prefill_chunk,
                            compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, group_experts=group,
                            ragged_moe=ragged, quant=args.quant,
                            paged_kernel=args.paged_kernel,
                            scheduler=args.scheduler)
    eng = ContinuousEngine(params, cfg, serve_cfg, packed=packed)
    finished, stats = eng.run(reqs, temperature=args.temperature)
    lat = latency_percentiles(finished)
    print(f"served {len(finished)} requests, {stats.generated_tokens} tokens "
          f"in {stats.wall_s:.2f}s ({stats.tokens_per_s:.1f} tok/s "
          f"incl. compile), slot util {stats.slot_utilization:.0%}, "
          f"p50 {lat['p50']:.0f}ms p99 {lat['p99']:.0f}ms")
    if serve_cfg.paged:
        print(f"paged: block_size={serve_cfg.block_size} "
              f"arena={serve_cfg.arena_blocks} blocks, "
              f"peak concurrency {stats.peak_concurrency}, "
              f"{stats.prefill_chunks} prefill chunks, "
              f"{stats.prompt_blocks_shared} prompt blocks shared "
              f"(hit rate {stats.prefix_hit_rate:.0%})")
    print("sample:", finished[0].tokens[:16], "...")


if __name__ == "__main__":
    main()
