"""Serving launcher: batched generation with a (optionally pruned) model.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --prune 0.5 --category composite
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--prune", type=float, default=0.0)
    ap.add_argument("--category", default="composite",
                    choices=["unstructured", "structured", "composite"])
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)

    if args.prune > 0:
        calib = corpus.calibration_batches(8, 4, args.prompt_len)
        art = run_ranking_controller(params, cfg, calib)
        res = run_pruning_controller(params, cfg, art, args.prune,
                                     category=args.category,
                                     align_channels=8)
        params, cfg = res.params, res.cfg
        print(f"pruned {args.prune:.0%} via {res.category}")

    eng = Engine(params, cfg, max_seq=args.prompt_len + args.new_tokens,
                 compute_dtype=jnp.float32, cache_dtype=jnp.float32)
    prompt = jnp.asarray(
        corpus.batch(0, args.batch, args.prompt_len)[:, :args.prompt_len])
    t0 = time.perf_counter()
    out = eng.generate(prompt, args.new_tokens,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0, -args.new_tokens:].tolist()[:16], "...")


if __name__ == "__main__":
    main()
