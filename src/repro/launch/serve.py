"""Serving launcher: static or continuous batching, optionally pruned.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --prune 0.5 --category composite --engine continuous --sparse

``--engine static`` runs the fixed-batch ``Engine`` (every prompt padded
to one length, one batch to completion). ``--engine continuous`` runs
the slot-pool ``ContinuousEngine``: mixed-length requests are admitted
FIFO into free KV slots and decoded together, one jitted step per tick.
``--sparse`` packs the pruned projections into block plans and routes
the serving MLPs through the Pallas block-sparse kernel.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.prune_controller import run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T
from repro.serve.batching import ContinuousEngine, latency_percentiles
from repro.serve.engine import Engine
from repro.serve.scheduler import Request
from repro.serve.sparse import flop_savings, pack_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=["static", "continuous"],
                    default="static")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / number of requests")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--prune", type=float, default=0.0)
    ap.add_argument("--category", default="composite",
                    choices=["unstructured", "structured", "composite"])
    ap.add_argument("--sparse", action="store_true",
                    help="serve pruned MLPs through the block-sparse kernel")
    ap.add_argument("--sparse-block", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)

    if args.prune > 0:
        calib = corpus.calibration_batches(8, 4, args.prompt_len)
        art = run_ranking_controller(params, cfg, calib)
        res = run_pruning_controller(params, cfg, art, args.prune,
                                     category=args.category,
                                     align_channels=8)
        params, cfg = res.params, res.cfg
        print(f"pruned {args.prune:.0%} via {res.category}")

    packed = None
    if args.sparse:
        packed = pack_model(params, cfg, block=args.sparse_block)
        print(f"packed {len(packed)} projections, "
              f"{flop_savings(packed):.0%} projection FLOPs skipped")

    max_seq = args.prompt_len + args.new_tokens
    if args.engine == "static":
        eng = Engine(params, cfg, max_seq=max_seq,
                     compute_dtype=jnp.float32, cache_dtype=jnp.float32,
                     packed=packed)
        prompt = jnp.asarray(
            corpus.batch(0, args.batch, args.prompt_len)[:, :args.prompt_len])
        t0 = time.perf_counter()
        out = eng.generate(prompt, args.new_tokens,
                           temperature=args.temperature)
        dt = time.perf_counter() - t0
        toks = args.batch * args.new_tokens
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s incl. compile)")
        print("sample:", out[0, -args.new_tokens:].tolist()[:16], "...")
        return

    # continuous: mixed-length requests through the slot pool
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.batch):
        s0 = int(rng.integers(max(args.prompt_len // 2, 1),
                              args.prompt_len + 1))
        prompt = corpus.batch(i, 1, s0)[0, :s0].tolist()
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=args.new_tokens))
    eng = ContinuousEngine(params, cfg, max_slots=args.max_slots,
                           max_seq=max_seq, compute_dtype=jnp.float32,
                           cache_dtype=jnp.float32, packed=packed)
    finished, stats = eng.run(reqs, temperature=args.temperature)
    lat = latency_percentiles(finished)
    print(f"served {len(finished)} requests, {stats.generated_tokens} tokens "
          f"in {stats.wall_s:.2f}s ({stats.tokens_per_s:.1f} tok/s "
          f"incl. compile), slot util {stats.slot_utilization:.0%}, "
          f"p50 {lat['p50']:.0f}ms p99 {lat['p99']:.0f}ms")
    print("sample:", finished[0].tokens[:16], "...")


if __name__ == "__main__":
    main()
