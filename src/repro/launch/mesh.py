"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))
