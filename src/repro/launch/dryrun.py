import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver.

For every (architecture x input shape) cell: build the step function
(train_step / prefill_step / serve_step), jit with the production
shardings, ``.lower().compile()`` on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh, and record memory_analysis / cost_analysis /
collective bytes. Cost terms for scanned stacks use the two-point period
extrapolation (launch/analysis.py).

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, ASSIGNED, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.distributed import sharding as SH
from repro.launch import analysis as AN
from repro.launch import specs as SPEC
from repro.launch.mesh import make_production_mesh
from repro.models.specs import ModelConfig
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.train import optimizer as OPT
from repro.train.train_step import make_train_step

# Per-(arch, shape) resource knobs (memory fitting at 16 GB/chip v5e).
# microbatches <= global_batch / dp_size (16) so every microbatch still
# shards over the data axis. seq_shard = Megatron-style sequence-parallel
# residual stream (activation stash /16).
DEFAULTS = {"microbatches": 16, "factored": False, "m_dtype": "float32",
            "seq_shard": False, "accum_dtype": "float32"}
OVERRIDES = {
    ("nemotron-4-340b", "train_4k"): {
        "factored": True, "m_dtype": "bfloat16", "seq_shard": True,
        "accum_dtype": "bfloat16"},
    ("qwen2-72b", "train_4k"): {"seq_shard": True},
    ("llama4-scout-17b-16e", "train_4k"): {"seq_shard": True},
    ("jamba-v0.1-52b", "train_4k"): {"seq_shard": True},
}


def knobs(arch: str, shape: str) -> dict:
    out = dict(DEFAULTS)
    out.update(OVERRIDES.get((arch, shape), {}))
    return out


# ------------------------------------------------------------ shardings

def _drop_axis(spec: P, axis_from_end: int) -> P:
    parts = list(spec)
    if len(parts) >= axis_from_end:
        del parts[len(parts) - axis_from_end]
    return P(*parts)


def opt_specs(pspec_tree, param_struct, opt_cfg: OPT.OptConfig):
    """PartitionSpec tree for the optimizer state, mirroring params."""
    m = pspec_tree
    if opt_cfg.factored:
        def v_spec(spec, leaf):
            if leaf.ndim >= 2 and leaf.shape[-1] >= 2 and leaf.shape[-2] >= 2:
                return {"row": _drop_axis(spec, 1), "col": _drop_axis(spec, 2)}
            return {"full": spec}
        v = jax.tree.map(v_spec, pspec_tree, param_struct,
                         is_leaf=lambda x: isinstance(x, P))
    else:
        v = pspec_tree
    return {"m": m, "v": v, "step": P()}


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------ builders

def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, kn: dict):
    """Returns (fn, args (structs), in_shardings, out_shardings, donate)."""
    pspecs = SH.param_specs(mesh, cfg)
    if shape.kind == "train" and kn.get("skip_opt"):
        # grad-only variant (cost measurement): one microbatch, no update
        from repro.train.train_step import make_loss_fn
        params_struct = SPEC.param_struct(cfg, dtype=jnp.float32)
        loss_fn = make_loss_fn(cfg, mesh=mesh, param_specs=pspecs)
        ins = SPEC.input_specs(cfg, shape)
        tok_shd = SH.input_sharding(mesh, shape.batch)

        def fn(params, tokens, labels, frontend_embeds=None):
            (_, (ce, _)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels,
                                       frontend_embeds)
            return grads, ce
        args = (params_struct, ins["tokens"], ins["labels"])
        in_shd = (to_shardings(mesh, pspecs), tok_shd, tok_shd)
        if "frontend_embeds" in ins:
            fe_shd = NamedSharding(mesh, P(tok_shd.spec[0], None, None))
            args = args + (ins["frontend_embeds"],)
            in_shd = in_shd + (fe_shd,)
        return fn, args, in_shd, (to_shardings(mesh, pspecs), None), ()

    if shape.kind == "train":
        opt_cfg = OPT.OptConfig(factored=kn["factored"], m_dtype=kn["m_dtype"])
        state_struct = SPEC.train_state_struct(cfg, opt_cfg)
        state_spec = {"params": pspecs,
                      "opt": opt_specs(pspecs, state_struct["params"], opt_cfg)}
        ins = SPEC.input_specs(cfg, shape)
        tok_shd = SH.input_sharding(mesh, shape.batch)
        bspec = tok_shd.spec
        fn = make_train_step(cfg, opt_cfg, n_microbatches=kn["microbatches"],
                             mesh=mesh, batch_spec=bspec,
                             accum_dtype=jnp.dtype(kn["accum_dtype"]),
                             param_specs=pspecs)
        args = (state_struct, ins["tokens"], ins["labels"])
        in_shd = (to_shardings(mesh, state_spec), tok_shd, tok_shd)
        if "frontend_embeds" in ins:
            fe_shd = NamedSharding(mesh, P(*((bspec[0],) + (None,) * 2)))
            args = args + (ins["frontend_embeds"],)
            in_shd = in_shd + (fe_shd,)
        out_shd = (to_shardings(mesh, state_spec), None)
        return fn, args, in_shd, out_shd, (0,)

    params = SPEC.param_struct(cfg, dtype=jnp.bfloat16)
    cache_shd = SH.cache_shardings(mesh, cfg, shape.batch)
    tok_shd = SH.input_sharding(mesh, shape.batch)
    if shape.kind == "prefill":
        ins = SPEC.input_specs(cfg, shape)
        fn0 = make_prefill_step(cfg)
        if "frontend_embeds" in ins:
            bspec = tok_shd.spec
            fe_shd = NamedSharding(mesh, P(bspec[0], None, None))
            fn = lambda p, t, c, fe: fn0(p, t, c, frontend_embeds=fe)
            args = (params, ins["tokens"], ins["cache"],
                    ins["frontend_embeds"])
            in_shd = (to_shardings(mesh, pspecs), tok_shd, cache_shd, fe_shd)
        else:
            fn = fn0
            args = (params, ins["tokens"], ins["cache"])
            in_shd = (to_shardings(mesh, pspecs), tok_shd, cache_shd)
        return fn, args, in_shd, (None, cache_shd), (2,)

    # decode
    ins = SPEC.input_specs(cfg, shape)
    fn = make_serve_step(cfg)
    args = (params, ins["cache"], ins["tokens"],
            jax.ShapeDtypeStruct((), jnp.int32))
    in_shd = (to_shardings(mesh, pspecs), cache_shd, tok_shd, None)
    return fn, args, in_shd, (None, cache_shd), (1,)


# ------------------------------------------------------------ the run

def compile_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, kn: dict):
    fn, args, in_shd, out_shd, donate = build_cell(cfg, shape, mesh, kn)
    jfn = jax.jit(fn, in_shardings=in_shd, out_shardings=out_shd,
                  donate_argnums=donate)
    t0 = time.perf_counter()
    from repro.distributed import axes as AX
    rules = dict(AX.DEFAULT_RULES)
    if kn.get("seq_shard"):
        rules["residual_seq"] = "model"
    with AX.use_mesh(mesh, rules):
        lowered = jfn.lower(*args)
    compiled = lowered.compile()
    dt = time.perf_counter() - t0
    return lowered, compiled, dt


def _cost_of(compiled) -> dict:
    return {**AN.cost_summary(compiled),
            "collective_bytes": AN.collective_bytes(compiled.as_text())["total"]}


def measure_cost(cfg: ModelConfig, shape: ShapeSpec, mesh, kn: dict) -> dict:
    """True per-step cost terms via unrolled depth-1/2 compiles.

    HloCostAnalysis counts `while` bodies once, so scanned stacks
    undercount. We instead compile *unrolled* variants with 1 and 2
    pattern periods (cost is affine in depth: f(d) = outside + d*layer),
    with a single microbatch for train, then recompose:
        train:  n_micro * [outside + P*layer] + optimizer_update
        serve:  outside + P*layer
    """
    if shape.kind == "train":
        micro = max(1, shape.batch // kn["microbatches"])
        shape_m = ShapeSpec(shape.name, "train", shape.seq, micro)
        kn_m = {**kn, "microbatches": 1, "skip_opt": True}
    else:
        shape_m = shape
        kn_m = kn
    costs = []
    for d in (1, 2):
        cfg_d = cfg.replace(n_periods=d, scan_layers=False)
        _, comp, _ = compile_cell(cfg_d, shape_m, mesh, kn_m)
        costs.append(_cost_of(comp))
        del comp
    layer = {k: costs[1][k] - costs[0][k] for k in costs[0]}
    outside = {k: costs[0][k] - layer[k] for k in costs[0]}
    per_call = {k: outside[k] + cfg.n_periods * layer[k] for k in costs[0]}
    if shape.kind == "train":
        n_micro = kn["microbatches"]
        total = {k: n_micro * per_call[k] for k in per_call}
        opt_cost = measure_opt_cost(cfg, mesh, kn)
        total = {k: total[k] + opt_cost.get(k, 0.0) for k in total}
        return total
    return per_call


def measure_opt_cost(cfg: ModelConfig, mesh, kn: dict) -> dict:
    """Cost of the optimizer update alone (runs once per step)."""
    opt_cfg = OPT.OptConfig(factored=kn["factored"], m_dtype=kn["m_dtype"])
    state_struct = SPEC.train_state_struct(cfg, opt_cfg)
    grads_struct = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32),
        state_struct["params"])
    pspecs = SH.param_specs(mesh, cfg)
    ospec = opt_specs(pspecs, state_struct["params"], opt_cfg)

    def fn(params, grads, opt_state):
        new_p, new_o, _ = OPT.apply_updates(params, grads, opt_state, opt_cfg)
        return new_p, new_o

    jfn = jax.jit(fn, in_shardings=(to_shardings(mesh, pspecs),
                                    to_shardings(mesh, pspecs),
                                    to_shardings(mesh, ospec)),
                  donate_argnums=(0, 2))
    comp = jfn.lower(state_struct["params"], grads_struct,
                     state_struct["opt"]).compile()
    out = _cost_of(comp)
    del comp
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             cost_periods: bool = True, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(shape, cfg):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic context "
                          "(DESIGN.md §5)"}
    kn = knobs(arch, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16", "knobs": kn}
    with mesh:
        lowered, compiled, dt = compile_cell(cfg, shape, mesh, kn)
        result["compile_seconds"] = dt
        result["memory"] = AN.memory_summary(compiled)
        result["cost_raw"] = AN.cost_summary(compiled)
        hlo = compiled.as_text()
        result["collectives_raw"] = AN.collective_bytes(hlo)
        del lowered, compiled, hlo

        if cost_periods:
            result["cost"] = measure_cost(cfg, shape, mesh, kn)
        else:
            result["cost"] = {**result["cost_raw"],
                              "collective_bytes":
                                  result["collectives_raw"]["total"]}
    if verbose:
        mem = result["memory"]
        print(f"[{arch} x {shape_name} @ {result['mesh']}] "
              f"compile {dt:.1f}s  "
              f"state {mem['argument_size_in_bytes'] / 2**30:.2f} GiB  "
              f"temp<= {mem['temp_size_in_bytes'] / 2**30:.2f} GiB  "
              f"peak {mem['peak_memory_in_bytes'] / 2**30:.2f} GiB  "
              f"flops {result['cost']['flops']:.3e}  "
              f"coll {result['cost']['collective_bytes']:.3e} B",
              flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-cost-periods", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                res = run_cell(arch, shape, multi_pod=mp,
                               cost_periods=not args.no_cost_periods
                               and not mp)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(res, f, indent=1)
            except Exception as e:                        # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS COMPILED.")


if __name__ == "__main__":
    main()
