"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --mesh host --steps 20     # sharded over local devices

Production notes (1000+ nodes): run under the cluster launcher with one
process per host; jax.distributed.initialize() picks up the coordinator;
the same code paths (mesh from launch.mesh, shardings from
distributed.sharding) then span pods. XLA flags for collective overlap:
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_enable_latency_hiding_scheduler=true
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.data.pipeline import SyntheticCorpus
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(scan_layers=cfg.scan_layers and not args.smoke)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    mesh = make_host_mesh() if args.mesh == "host" else None
    trainer = Trainer(cfg, opt, corpus.batches(args.batch, args.seq),
                      ckpt=ckpt, ckpt_every=args.ckpt_every,
                      n_microbatches=args.microbatches,
                      compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16,
                      mesh=mesh,
                      log_fn=lambda s, m: print(
                          f"step {s:5d}  loss {float(m['loss']):.4f}  "
                          f"lr {float(m['lr']):.2e}", flush=True)
                      if s % 10 == 0 else None)
    report = trainer.run(args.steps)
    print(f"\ndone: {report.steps_run} steps, final loss "
          f"{report.losses[-1]:.4f}, stragglers flagged: "
          f"{len(report.stragglers)}, preempted: {report.preempted}")


if __name__ == "__main__":
    main()
