"""Recipe-sweep launcher: one RC profile fanned across a recipe grid.

The paper's E5 overhead win operationalised: ``profile_model`` runs at
most once per sweep (zero times when ``--rank-artifact`` points at a
saved profile), every grid point reuses the same
:class:`~repro.core.rank_controller.RankArtifact`, and each point's
quality/size trade-off lands in one Pareto table.

  # 6-point grid (3 p-levels x 2 categories) from the golden recipe
  PYTHONPATH=src python -m repro.launch.sweep --smoke \
      --recipe recipes/golden-smoke.json \
      --p 0.3,0.5,0.7 --category composite,unstructured \
      --out results/sweep

  # grid from JSON; cache the profile for later sweeps of the same model
  PYTHONPATH=src python -m repro.launch.sweep --smoke \
      --recipe recipes/golden-smoke.json --grid recipes/sweep-grid.json \
      --rank-artifact results/profile --out results/sweep

``--rank-artifact DIR`` loads the profile when DIR holds one, and saves
the freshly computed profile there otherwise — the second sweep never
re-profiles. Outputs under ``--out``: ``points/<label>/`` PrunedArtifact
bundles, ``profile/`` the reusable RankArtifact, ``pareto.csv`` +
``pareto.md`` with one row per point (ppl, acc, bytes_after,
prune_seconds, quality_per_byte, pareto flag).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.rank_controller import RankArtifact
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.core.sweep import GridSpec, pareto_markdown, run_sweep
from repro.models import transformer as T


def _split(text, cast):
    return tuple(cast(x) for x in text.split(",") if x)


def grid_from_args(args: argparse.Namespace) -> GridSpec:
    if args.grid:
        return GridSpec.load(args.grid)
    return GridSpec(
        p=_split(args.p or "", float),
        category=_split(args.category or "", str),
        selector=_split(args.selector or "", str),
        granularity=_split(args.granularity or "", str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--recipe", default=None, metavar="JSON",
                    help="base PruneRecipe JSON (axes not in the grid "
                         "keep its values)")
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--grid", default=None, metavar="JSON",
                    help="GridSpec JSON file (overrides the axis flags)")
    ap.add_argument("--p", default=None,
                    help="comma-separated pruning levels, e.g. 0.3,0.5,0.7")
    ap.add_argument("--category", default=None,
                    help="comma-separated categories, e.g. "
                         "composite,unstructured")
    ap.add_argument("--selector", default=None,
                    help="comma-separated selectors, e.g. wanda,sparsegpt")
    ap.add_argument("--granularity", default=None,
                    help="comma-separated granularities")
    ap.add_argument("--rank-artifact", default=None, metavar="DIR",
                    help="load the RC profile from DIR if present, else "
                         "profile once and save it there")
    ap.add_argument("--calib-samples", type=int, default=32)
    ap.add_argument("--out", default="results/sweep",
                    help="sweep output directory (artifacts + Pareto)")
    ap.add_argument("--fresh", action="store_true",
                    help="re-execute every grid point even when its "
                         "points/<label>/ bundle already exists (default: "
                         "resume — existing bundles are skipped)")
    args = ap.parse_args()

    if args.recipe:
        base = PruneRecipe.load(args.recipe)
    else:
        base = PruneRecipe(
            arch=args.arch, p=0.5, category="composite",
            calibration=CalibrationSpec(n_samples=args.calib_samples,
                                        batch_size=8, seq_len=64))
    grid = grid_from_args(args)

    cfg = (get_smoke_config(base.arch) if args.smoke
           else get_config(base.arch))
    cfg = cfg.replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)

    rank_artifact = None
    if args.rank_artifact and RankArtifact.is_artifact(args.rank_artifact):
        rank_artifact = RankArtifact.load(args.rank_artifact)
        print(f"profile: loaded from {args.rank_artifact} "
              f"({rank_artifact.n_tokens} calibration tokens)")

    print(f"sweep: {grid.n_points()} points over {cfg.name}")
    res = run_sweep(base, grid, params, cfg, out_dir=args.out,
                    rank_artifact=rank_artifact, resume=not args.fresh,
                    progress=print)

    if res.profiled:
        print(f"profile: computed once "
              f"({res.rank_artifact.profile_seconds:.1f}s), reused for "
              f"all {len(res.rows)} points")
    # (re-)cache when freshly profiled OR when the sweep lazily attached
    # hessians to a hessian-free cached profile — the next sweep pays
    # neither the profile nor the hessian pass
    gained_hessians = (rank_artifact is not None
                       and rank_artifact.hessians is None
                       and res.rank_artifact.hessians is not None)
    if args.rank_artifact and (res.profiled or gained_hessians):
        res.rank_artifact.save(args.rank_artifact)
        print(f"profile: cached to {args.rank_artifact}")
    print()
    print(pareto_markdown(res.rows))
    print(f"pareto csv: {res.csv_path}")


if __name__ == "__main__":
    main()
