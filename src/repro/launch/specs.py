"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation: the same pattern shannon/kernels uses — weak-type-
correct structs that jit().lower() accepts directly.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.models.specs import ModelConfig
from repro.train import optimizer as OPT


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def frontend_len(cfg: ModelConfig, seq: int) -> int:
    return int(cfg.frontend_frac * seq) if cfg.frontend else 0


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                cache_dtype=jnp.bfloat16) -> dict:
    """Returns the kwargs (as ShapeDtypeStructs) for the step function of
    this shape kind."""
    B, S = shape.batch, shape.seq
    if shape.kind == "train":
        out = {"tokens": sds((B, S), jnp.int32),
               "labels": sds((B, S), jnp.int32)}
        F = frontend_len(cfg, S)
        if F:
            out["frontend_embeds"] = sds((B, F, cfg.d_model), jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32),
               "cache": cache_specs_struct(cfg, B, S, cache_dtype)}
        F = frontend_len(cfg, S)
        if F:
            out["frontend_embeds"] = sds((B, F, cfg.d_model), jnp.bfloat16)
        return out
    if shape.kind == "decode":
        return {"cache": cache_specs_struct(cfg, B, S, cache_dtype),
                "tokens": sds((B, 1), jnp.int32),
                "cache_index": sds((), jnp.int32)}
    raise ValueError(shape.kind)


def cache_specs_struct(cfg: ModelConfig, batch: int, s_max: int,
                       dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, s_max, dtype=dtype))


def param_struct(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg, dtype=dtype))


def train_state_struct(cfg: ModelConfig, opt_cfg: OPT.OptConfig,
                       param_dtype=jnp.float32):
    def build():
        params = T.init_model(jax.random.PRNGKey(0), cfg, dtype=param_dtype)
        return {"params": params, "opt": OPT.init_opt(params, opt_cfg)}
    return jax.eval_shape(build)
