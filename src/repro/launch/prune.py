"""Mosaic pruning launcher: RC -> PC -> deployment-ready SLM checkpoint.

  PYTHONPATH=src python -m repro.launch.prune --arch gemma-2b --smoke \
      --p 0.6 --category composite --out results/pruned_gemma
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.common.tree import param_bytes, param_count
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.prune_controller import Platform, run_pruning_controller
from repro.core.rank_controller import run_ranking_controller
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as T

PLATFORMS = {
    "cloud": Platform("cloud", 80 << 30, has_sparse_accel=True, tp_size=16),
    "edge": Platform("edge", 4 << 30),
    "mobile": Platform("mobile", 8 << 30),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--p", type=float, required=True)
    ap.add_argument("--category", default=None,
                    choices=[None, "unstructured", "structured", "composite"])
    ap.add_argument("--platform", default=None, choices=sorted(PLATFORMS))
    ap.add_argument("--granularity", default="projection",
                    choices=["global", "layer", "projection"])
    ap.add_argument("--selector", default="wanda",
                    choices=["magnitude", "wanda", "sparsegpt"])
    ap.add_argument("--calib-samples", type=int, default=32)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    calib = corpus.calibration_batches(args.calib_samples, 8, 64)

    print(f"RC: profiling {cfg.name} "
          f"({param_count(params) / 1e6:.1f}M params)...")
    art = run_ranking_controller(params, cfg, calib,
                                 want_hessians=args.selector == "sparsegpt")
    print(f"RC done in {art.profile_seconds:.1f}s over {art.n_tokens} tokens")

    platform = PLATFORMS.get(args.platform) if args.platform else None
    res = run_pruning_controller(params, cfg, art, args.p,
                                 platform=platform, category=args.category,
                                 granularity=args.granularity,
                                 selector=args.selector, align_channels=8)
    print(f"PC: category={res.category} granularity={res.granularity} "
          f"in {res.prune_seconds:.1f}s")
    print(f"params {param_count(params)} -> {param_count(res.params)}  "
          f"bytes {param_bytes(params)} -> {param_bytes(res.params)}")
    if args.out:
        mgr = CheckpointManager(args.out, keep=1)
        mgr.save(0, res.params, blocking=True,
                 extra_meta={"category": res.category, "p": args.p})
        print(f"saved pruned model to {args.out}")


if __name__ == "__main__":
    main()
