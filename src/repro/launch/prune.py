"""Mosaic pruning launcher: one PruneRecipe drives RC -> planner ->
category -> pack -> report, and saves a deployment-ready PrunedArtifact.

  # declarative: the recipe JSON is the whole configuration
  PYTHONPATH=src python -m repro.launch.prune --smoke \
      --recipe recipes/golden-smoke.json --out results/pruned_gemma

  # or assemble the recipe from flags (legacy CLI, same pipeline)
  PYTHONPATH=src python -m repro.launch.prune --arch gemma-2b --smoke \
      --p 0.6 --category composite --out results/pruned_gemma

  # or target a deployment platform: a bare --platform loads the
  # checked-in preset recipe (recipes/cloud.json | edge.json |
  # mobile.json) whose category defers to PC step 9's memory-driven
  # selection for that platform; --p overrides the preset's target
  PYTHONPATH=src python -m repro.launch.prune --smoke --platform edge \
      --out results/pruned_edge

The saved artifact directory is everything ``launch/serve.py
--artifact`` needs: pruned params, pruned config, block plans, recipe,
and report.json (incl. ``prune_seconds`` — the paper's model-production
-time claim, tracked per PR in CI).
"""
from __future__ import annotations

import argparse
import json
import logging
import pathlib

import jax

from repro.common.tree import param_bytes, param_count
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.pipeline import MosaicPipeline
from repro.core.prune_controller import PLATFORMS
from repro.core.recipe import CalibrationSpec, PruneRecipe
from repro.models import transformer as T


def recipe_from_args(args: argparse.Namespace) -> PruneRecipe:
    if args.recipe:
        recipe = PruneRecipe.load(args.recipe)
        if args.p is not None:
            recipe = recipe.replace(p=args.p)
        return recipe
    if args.platform:
        # a bare --platform resolves the checked-in preset recipe for
        # that deployment target (recipes/<platform>.json); explicit
        # --recipe wins, and --p still overrides the preset's target
        preset = pathlib.Path(__file__).parents[3] / "recipes" \
            / f"{args.platform}.json"
        if preset.is_file():
            recipe = PruneRecipe.load(preset)
            if args.p is not None:
                recipe = recipe.replace(p=args.p)
            return recipe
    if args.p is None:
        raise SystemExit("either --recipe, --platform (with a preset in "
                         "recipes/), or --p is required")
    return PruneRecipe(
        arch=args.arch, p=args.p, category=args.category,
        granularity=args.granularity, selector=args.selector,
        platform=args.platform, align_channels=args.align_channels,
        block=args.block,
        calibration=CalibrationSpec(n_samples=args.calib_samples,
                                    batch_size=8, seq_len=64))


def main() -> None:
    # surface INFO logs (e.g. pack_model's skipped-projection summary)
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--recipe", default=None, metavar="JSON",
                    help="PruneRecipe JSON file (overrides the flags below)")
    ap.add_argument("--arch", choices=list_archs(), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--p", type=float, default=None)
    ap.add_argument("--category", default=None,
                    choices=[None, "unstructured", "structured", "composite"])
    ap.add_argument("--platform", default=None, choices=sorted(PLATFORMS))
    ap.add_argument("--granularity", default="projection",
                    choices=["global", "layer", "projection"])
    ap.add_argument("--selector", default="wanda",
                    choices=["magnitude", "wanda", "wanda_block", "sparsegpt"])
    ap.add_argument("--align-channels", type=int, default=8)
    ap.add_argument("--block", type=int, default=128,
                    help="block-sparse tile: pack-stage plan size AND the "
                         "wanda_block mask tile — must divide the model's "
                         "projection dims (use 16 for smoke configs)")
    ap.add_argument("--calib-samples", type=int, default=32)
    ap.add_argument("--out", default=None,
                    help="directory to save the PrunedArtifact bundle")
    args = ap.parse_args()

    recipe = recipe_from_args(args)
    cfg = (get_smoke_config(recipe.arch) if args.smoke
           else get_config(recipe.arch))
    cfg = cfg.replace(scan_layers=False)
    params = T.init_model(jax.random.PRNGKey(0), cfg)

    print(f"pipeline: {list(recipe.stages)} over {cfg.name} "
          f"({param_count(params) / 1e6:.1f}M params)")
    artifact = MosaicPipeline(recipe).run(params, cfg)
    rep = artifact.report              # {} when 'report' not in stages
    if rep.get("profile_seconds") is not None:
        print(f"RC: {rep['profile_seconds']:.1f}s over "
              f"{rep['calibration_tokens']} tokens")
    print(f"PC: category={rep.get('category')} "
          f"granularity={recipe.granularity} "
          f"in {rep.get('prune_seconds', 0.0):.1f}s")
    if rep.get("pack"):
        pk = rep["pack"]
        print(f"pack: {pk['n_packed']} plans (block {pk['block']}), "
              f"{pk['n_skipped']} skipped ({pk['skipped_params']} params), "
              f"{pk['flop_savings']:.0%} FLOPs skippable")
    print(f"params {param_count(params)} -> {param_count(artifact.params)}  "
          f"bytes {param_bytes(params)} -> {param_bytes(artifact.params)}")
    print(f"pipeline total {rep.get('pipeline_seconds', 0.0):.1f}s")
    if args.out:
        artifact.save(args.out)
        print(f"saved PrunedArtifact to {args.out}")
        print(json.dumps({k: rep.get(k) for k in
                          ("arch", "category", "prune_seconds",
                           "pipeline_seconds")}))


if __name__ == "__main__":
    main()
