"""Train step: loss + grad with microbatch accumulation and remat.

Gradient accumulation serves two purposes at scale: activation memory
(global_batch 256 x 4k tokens never lives at once) and compute/comm
overlap (per-microbatch reduce-scatter overlaps the next microbatch's
backward under XLA's latency-hiding scheduler).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.specs import ModelConfig
from repro.train import optimizer as OPT


def make_loss_fn(cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                 aux_weight: float = 0.01, mesh=None, param_specs=None):
    def cast_params(params):
        # cast fp32 masters to compute dtype *before* the FSDP all-gathers
        # so collectives move bf16, not fp32 (2x ICI traffic saved); the
        # cast is differentiable so grads land back on the fp32 masters.
        # The explicit sharding constraint keeps the convert shard-local —
        # without it GSPMD gathers fp32 and converts afterwards.
        def one(x, spec=None):
            if not jnp.issubdtype(x.dtype, jnp.floating):
                return x
            y = x.astype(compute_dtype)
            if mesh is not None and spec is not None:
                from jax.sharding import NamedSharding
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, spec))
            return y
        if param_specs is not None:
            from jax.sharding import PartitionSpec as P
            return jax.tree.map(one, params, param_specs,
                                is_leaf=lambda x: hasattr(x, "dtype"))
        return jax.tree.map(one, params)

    def loss_fn(params, tokens, labels, frontend_embeds=None):
        params = cast_params(params)
        return T.loss_fn(params, cfg, tokens, labels,
                         frontend_embeds=frontend_embeds,
                         compute_dtype=compute_dtype, aux_weight=aux_weight)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OPT.OptConfig,
                    n_microbatches: int = 1,
                    compute_dtype=jnp.bfloat16,
                    aux_weight: float = 0.01,
                    mesh=None, batch_spec=None,
                    accum_dtype=jnp.float32, param_specs=None):
    """Returns train_step(state, tokens, labels) -> (state, metrics).

    state = {'params': ..., 'opt': ...}. When n_microbatches > 1 the batch
    is split on the leading axis and gradients accumulate in fp32.
    """
    loss_fn = make_loss_fn(cfg, compute_dtype, aux_weight, mesh=mesh,
                           param_specs=param_specs)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_micro(x):
        if mesh is None or batch_spec is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(*((None,) + tuple(batch_spec)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def train_step(state, tokens, labels, frontend_embeds=None):
        params = state["params"]
        if n_microbatches == 1:
            (loss, (ce, aux)), grads = grad_fn(params, tokens, labels,
                                               frontend_embeds)
        else:
            B = tokens.shape[0]
            assert B % n_microbatches == 0
            mb = B // n_microbatches
            tok = constrain_micro(
                tokens.reshape(n_microbatches, mb, *tokens.shape[1:]))
            lab = constrain_micro(
                labels.reshape(n_microbatches, mb, *labels.shape[1:]))
            fe = None
            if frontend_embeds is not None:
                fe = constrain_micro(frontend_embeds.reshape(
                    n_microbatches, mb, *frontend_embeds.shape[1:]))
            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, accum_dtype), params)

            def body(carry, xs):
                gacc, lacc, ceacc, auxacc = carry
                t, l, f = xs
                (lo, (ce_i, aux_i)), g = grad_fn(params, t, l, f)
                gacc = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32) / n_microbatches
                                  ).astype(accum_dtype),
                    gacc, g)
                return (gacc, lacc + lo / n_microbatches,
                        ceacc + ce_i / n_microbatches,
                        auxacc + aux_i / n_microbatches), None

            (grads, loss, ce, aux), _ = jax.lax.scan(
                body, (zeros, 0.0, 0.0, 0.0), (tok, lab, fe))
        new_params, new_opt, stats = OPT.apply_updates(
            params, grads, state["opt"], opt_cfg)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **stats}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, opt_cfg: OPT.OptConfig,
                     param_dtype=jnp.float32) -> dict:
    params = T.init_model(key, cfg, dtype=param_dtype)
    return {"params": params, "opt": OPT.init_opt(params, opt_cfg)}
