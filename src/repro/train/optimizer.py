"""AdamW from scratch (no optax), with:
  - linear-warmup + cosine-decay schedule
  - global-norm gradient clipping
  - optional factored second moment (Adafactor-style) so 340B-scale
    optimizer state fits a single pod (DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    factored: bool = False       # factored v for >=2D leaves
    m_dtype: str = "float32"     # bf16 halves momentum memory at 340B scale


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _factorable(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] >= 2 and x.shape[-2] >= 2


def init_opt(params, cfg: OptConfig) -> dict:
    m_dtype = jnp.dtype(cfg.m_dtype)
    m = jax.tree.map(lambda x: jnp.zeros_like(x, m_dtype), params)
    if cfg.factored:
        def init_v(x):
            if _factorable(x):
                return {"row": jnp.zeros(x.shape[:-1], jnp.float32),
                        "col": jnp.zeros(x.shape[:-2] + x.shape[-1:],
                                         jnp.float32)}
            return {"full": jnp.zeros_like(x, jnp.float32)}
        v = jax.tree.map(init_v, params)
    else:
        v = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def _vhat_full(v, g2, b2):
    return b2 * v + (1 - b2) * g2


def apply_updates(params, grads, opt_state: dict, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    m_dtype = jnp.dtype(cfg.m_dtype)
    new_m = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g.astype(jnp.float32)).astype(m_dtype),
        opt_state["m"], grads)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        if cfg.factored:
            if _factorable(p):
                row = _vhat_full(v["row"], jnp.mean(jnp.square(g32), -1), cfg.b2)
                col = _vhat_full(v["col"], jnp.mean(jnp.square(g32), -2), cfg.b2)
                new_v = {"row": row, "col": col}
                denom = jnp.sqrt(
                    (row[..., :, None] * col[..., None, :]) /
                    jnp.maximum(jnp.mean(row, -1, keepdims=True)[..., None],
                                1e-30) / b2c) + cfg.eps
            else:
                full = _vhat_full(v["full"], jnp.square(g32), cfg.b2)
                new_v = {"full": full}
                denom = jnp.sqrt(full / b2c) + cfg.eps
        else:
            new_v = _vhat_full(v, jnp.square(g32), cfg.b2)
            denom = jnp.sqrt(new_v / b2c) + cfg.eps
        mhat = m.astype(jnp.float32) / b1c
        delta = mhat / denom + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(new_m)
    flat_v = treedef.flatten_up_to(opt_state["v"]) if cfg.factored \
        else jax.tree.leaves(opt_state["v"])
    new_p, new_v = zip(*[upd(p, g, m, v) for p, g, m, v in
                         zip(flat_p, flat_g, flat_m, flat_v)])
    new_params = jax.tree.unflatten(treedef, new_p)
    new_vt = jax.tree.unflatten(treedef, new_v)
    new_state = {"m": new_m, "v": new_vt, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
