"""Training loop: data prefetch, checkpoint/restart, preemption handling,
straggler monitoring. The production driver behind launch/train.py and the
E4/E5 benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher
from repro.distributed.fault import PreemptionHandler, StragglerMonitor
from repro.models.specs import ModelConfig
from repro.train import optimizer as OPT
from repro.train.train_step import init_train_state, make_train_step


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_step: int
    losses: list
    step_seconds: list
    stragglers: list
    preempted: bool


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: OPT.OptConfig,
                 data_it: Iterator, ckpt: Optional[CheckpointManager] = None,
                 ckpt_every: int = 100, n_microbatches: int = 1,
                 compute_dtype=None, seed: int = 0,
                 log_fn: Optional[Callable] = None,
                 prefetch: bool = True, mesh=None, batch_spec=None,
                 async_checkpoint: bool = True):
        import jax.numpy as jnp
        compute_dtype = compute_dtype or jnp.bfloat16
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.async_checkpoint = async_checkpoint
        self.log_fn = log_fn or (lambda *_: None)
        self.data = Prefetcher(data_it) if prefetch else data_it
        self.train_step = jax.jit(make_train_step(
            cfg, opt_cfg, n_microbatches=n_microbatches,
            compute_dtype=compute_dtype, mesh=mesh, batch_spec=batch_spec),
            donate_argnums=(0,))
        self.state = init_train_state(jax.random.PRNGKey(seed), cfg, opt_cfg)
        self.step = 0
        self.preemption = PreemptionHandler().install()
        self.straggler = StragglerMonitor()
        if ckpt is not None and ckpt.latest_step() is not None:
            self.state = ckpt.restore(self.state)
            self.step = ckpt.meta()["step"]

    def run(self, n_steps: int) -> TrainReport:
        losses, times = [], []
        preempted = False
        target = self.step + n_steps
        while self.step < target:
            try:
                tokens, labels = next(self.data)
            except StopIteration:
                break
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, tokens, labels)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            losses.append(loss)
            times.append(dt)
            self.straggler.record(self.step, dt)
            self.log_fn(self.step, metrics)
            if self.ckpt and self.step % self.ckpt_every == 0:
                self._save()
            if self.preemption.should_stop:
                self._save()
                preempted = True
                break
        if self.ckpt:
            self._save()
            self.ckpt.wait()
        return TrainReport(steps_run=len(losses), final_step=self.step,
                           losses=losses, step_seconds=times,
                           stragglers=list(self.straggler.flagged),
                           preempted=preempted)

    def _save(self) -> None:
        if self.ckpt:
            self.ckpt.save(self.step, self.state,
                           blocking=not self.async_checkpoint,
                           extra_meta={"step": self.step})
