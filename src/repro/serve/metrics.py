"""Per-stage serving observability: ring-buffer series + percentiles.

The engine tick loop is the only place that sees every stage of a
request's life — queue wait, prefill, decode — and every tick-level
gauge (active slots, free blocks, prefill backlog, tokens/s). This
module gives it somewhere cheap to put those numbers: a
:class:`MetricsRegistry` of fixed-size ring buffers (latency samples),
monotonic counters, and last-value gauges, summarised on demand as one
JSON-safe dict. The gateway's ``/metrics`` endpoint and
``benchmarks/run.py --json`` both export this summary, so per-request
latency visibility is the same surface everywhere (deepsparse's
``_TextGenerationTimings`` per-stage timers are the model).

Pure host-side numpy — recording must never touch the jitted hot loop's
device streams.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

# the canonical per-request stage series, milliseconds (recorded by the
# engine for every Finished request; names are part of the wire schema)
REQUEST_STAGES = ("request.queue_ms", "request.prefill_ms",
                  "request.decode_ms", "request.total_ms")
# per-tick gauges (recorded each decode tick / loop iteration)
TICK_GAUGES = ("tick.active_slots", "tick.prefill_backlog",
               "tick.free_blocks", "tick.tokens_per_s")


class RingBuffer:
    """Fixed-capacity float samples; overwrites the oldest."""

    def __init__(self, capacity: int = 1024):
        self._buf = np.zeros(capacity, np.float64)
        self._n = 0                     # total samples ever observed

    def add(self, value: float) -> None:
        self._buf[self._n % len(self._buf)] = value
        self._n += 1

    def values(self) -> np.ndarray:
        if self._n >= len(self._buf):
            return self._buf
        return self._buf[:self._n]

    def __len__(self) -> int:
        return min(self._n, len(self._buf))

    @property
    def total(self) -> int:
        return self._n


class MetricsRegistry:
    """Named series (ring buffers), counters, and gauges.

    ``observe`` feeds a distribution series; ``count`` bumps a
    monotonic counter; ``gauge`` records a last-value sample.
    ``summary()`` renders everything as one nested JSON-safe dict with
    percentile digests for each series.
    """

    def __init__(self, capacity: int = 1024,
                 percentiles: Iterable[int] = (50, 90, 99)):
        self.capacity = capacity
        self.pcts = tuple(percentiles)
        self.series: dict[str, RingBuffer] = {}
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}

    # ---------------------------------------------------------- record

    def observe(self, name: str, value: float) -> None:
        buf = self.series.get(name)
        if buf is None:
            buf = self.series[name] = RingBuffer(self.capacity)
        buf.add(float(value))

    def count(self, name: str, inc: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)
        self.observe(name, value)       # gauges keep a history too

    # ---------------------------------------------------------- export

    def percentiles(self, name: str,
                    p: Iterable[int] = (50, 99)) -> dict:
        buf = self.series.get(name)
        if buf is None or not len(buf):
            return {f"p{q}": 0.0 for q in p}
        vals = buf.values()
        return {f"p{q}": float(np.percentile(vals, q)) for q in p}

    def summary(self) -> dict:
        """JSON-safe digest of every series/counter/gauge."""
        out: dict = {"series": {}, "counters": dict(self.counters),
                     "gauges": dict(self.gauges)}
        for name, buf in sorted(self.series.items()):
            vals = buf.values()
            digest = {"count": int(buf.total)}
            if len(vals):
                digest.update({
                    "mean": float(np.mean(vals)),
                    "min": float(np.min(vals)),
                    "max": float(np.max(vals)),
                })
                digest.update({f"p{q}": float(np.percentile(vals, q))
                               for q in self.pcts})
            out["series"][name] = digest
        return out

    def reset(self) -> None:
        self.series.clear()
        self.counters.clear()
        self.gauges.clear()


# --------------------------------------------------------- request stages

def stage_latencies_ms(finished) -> dict:
    """Per-stage latencies of one ``scheduler.Finished`` record, ms.

    queue   = arrival -> admission (slot + blocks granted)
    prefill = admission -> first sampled token
    decode  = first token -> finish
    total   = arrival -> finish
    """
    req = finished.request
    return {
        "queue_ms": (finished.admitted_at - req.arrival) * 1e3,
        "prefill_ms": (finished.first_token_at
                       - finished.admitted_at) * 1e3,
        "decode_ms": (finished.finished_at
                      - finished.first_token_at) * 1e3,
        "total_ms": (finished.finished_at - req.arrival) * 1e3,
    }


def observe_finished(metrics: Optional[MetricsRegistry], finished) -> None:
    """Record one finished request's stage latencies into ``metrics``."""
    if metrics is None:
        return
    stages = stage_latencies_ms(finished)
    for key, value in stages.items():
        metrics.observe(f"request.{key}", value)
    metrics.count("requests.finished")
    metrics.count(f"requests.finish_reason.{finished.reason}")


def latency_percentiles(finished: list, p=(50, 99)) -> dict:
    """Request-completion latency (arrival -> finish) percentiles, ms.

    Moved here from ``repro.serve.batching`` (which re-exports it): the
    metrics layer owns every latency digest now.
    """
    lats = [(f.finished_at - f.request.arrival) * 1e3 for f in finished]
    if not lats:
        return {f"p{q}": 0.0 for q in p}
    return {f"p{q}": float(np.percentile(lats, q)) for q in p}


def queue_percentiles(finished: list, p=(50, 99)) -> dict:
    """Queue-wait (arrival -> admission) percentiles, ms."""
    lats = [(f.admitted_at - f.request.arrival) * 1e3 for f in finished]
    if not lats:
        return {f"p{q}": 0.0 for q in p}
    return {f"p{q}": float(np.percentile(lats, q)) for q in p}


def slo_attainment(finished: list) -> float:
    """Fraction of deadline-carrying requests that finished within
    ``request.deadline_ms`` of arrival. 1.0 when none carry deadlines."""
    dl = [f for f in finished if f.request.deadline_ms is not None]
    if not dl:
        return 1.0
    met = sum(1 for f in dl
              if (f.finished_at - f.request.arrival) * 1e3
              <= f.request.deadline_ms)
    return met / len(dl)
