"""Block-sparse serving path: run a Mosaic-pruned (``wanda_block`` /
composite) model's projections through the Pallas block-sparse kernel.

``pack_model`` walks the pruned projections once (the PC's Post-Pruning
Optimizer step, Fig. 6 #10), builds the per-projection block plans, and
``sparse_apply_mlp`` executes the feed-forward with zero tiles skipped.
On TPU the skipped tiles are real MXU/HBM savings; on CPU the kernel
runs in interpret mode (tests assert exact agreement with dense).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_get
from repro.core.registry import projections
from repro.kernels.block_sparse.ops import (block_mask_from_weight_mask,
                                            blocksparse_matmul, plan_blocks)
from repro.models.specs import ModelConfig


@dataclasses.dataclass
class PackedProjection:
    counts: jax.Array          # (N/bn,)
    indices: jax.Array         # (N/bn, max_nnz)
    block: int
    density: float             # fraction of nonzero tiles


def pack_projection(w, block: int = 128) -> Optional[PackedProjection]:
    """Build the kernel's block plan from a pruned weight. Returns None
    when the (2-D-folded) weight doesn't tile evenly."""
    w2 = np.asarray(w).reshape(w.shape[0], -1)
    K, N = w2.shape
    if K % block or N % block:
        return None
    bm = block_mask_from_weight_mask(w2 != 0, block, block)
    counts, indices = plan_blocks(bm)
    return PackedProjection(counts=counts, indices=indices, block=block,
                            density=float(bm.mean()))


def pack_model(params, cfg: ModelConfig, block: int = 128) -> dict:
    """{(layer, name): PackedProjection} for every tileable projection."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    packed = {}
    for proj in projections(cfg):
        if proj.expert_axis is not None:
            continue                      # expert weights: per-expert plans
        p = pack_projection(tree_get(params, proj.path), block)
        if p is not None:
            packed[proj.key] = p
    return packed


def sparse_linear(x, w, packed: PackedProjection, interpret: bool = True):
    """y = x @ w through the block-sparse kernel. x: (..., K); w: (K, N)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm = packed.block
    pad_m = (-M) % bm
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    y = blocksparse_matmul(x2, w.reshape(K, -1), packed.counts,
                           packed.indices, block_m=bm, block_k=bm,
                           block_n=bm, interpret=interpret)
    if pad_m:
        y = y[:M]
    return y.reshape(*lead, -1)


def sparse_apply_mlp(block_params: dict, spec, x, packed_layer: dict,
                     layer: int, interpret: bool = True):
    """Feed-forward through the kernel (gate/up/down as available)."""
    from repro.models.layers import activation
    mlp = block_params["mlp"]
    dtype = x.dtype

    def lin(name, inp):
        w = mlp[name].astype(dtype)
        key = (layer, name)
        if key in packed_layer:
            return sparse_linear(inp, w, packed_layer[key], interpret)
        return inp @ w

    up = lin("up", x)
    if spec.gated:
        h = activation(spec.act, lin("gate", x)) * up
    else:
        h = activation(spec.act, up)
    return lin("down", h)


def flop_savings(packed: dict) -> float:
    """Mean fraction of projection FLOPs the kernel skips."""
    if not packed:
        return 0.0
    return float(np.mean([1.0 - p.density for p in packed.values()]))
