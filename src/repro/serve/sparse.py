"""Block-sparse serving path: run a Mosaic-pruned (``wanda_block`` /
composite) model's projections through the Pallas block-sparse kernel.

``pack_model`` walks the pruned projections once (the PC's Post-Pruning
Optimizer step, Fig. 6 #10), builds the per-projection block plans —
including a per-expert plan stack for every MoE expert weight — and
``sparse_apply_ffn`` executes the feed-forward with zero tiles skipped
(``sparse_apply_mlp`` for dense-MLP layers, ``sparse_apply_moe`` inside
the MoE dispatch). MoE expert matmuls default to the *grouped*
block-sparse kernel — all E experts in one launch, driven directly by
the stacked plan — with the per-expert launch loop kept as the
``group_experts=False`` fallback (and the reference in equivalence
tests). On TPU the skipped tiles are real MXU/HBM savings; on CPU the
kernels run in interpret mode (tests assert exact agreement with dense).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_get, tree_set
from repro.core.registry import projections
from repro.kernels.block_sparse.ops import (block_mask_from_weight_mask,
                                            blocksparse_matmul,
                                            gather_kept_tiles, plan_blocks,
                                            plan_slots,
                                            quant_blocksparse_matmul)
from repro.models.specs import ModelConfig


@dataclasses.dataclass
class PackedProjection:
    """One projection's block plan. With ``quant="int8"`` the plan also
    carries the kept tiles themselves — compacted int8 storage plus the
    per-tile power-of-two scales and the slot map locating column
    ``n``'s step-``s`` tile — so the serving path never touches the
    dense weight."""
    counts: jax.Array          # (N/bn,)
    indices: jax.Array         # (N/bn, max_nnz)
    block: int
    density: float             # fraction of nonzero tiles
    quant: str = "none"        # "none" | "int8" (kept-tile storage)
    tiles: Optional[jax.Array] = None   # (T, block, block) int8
    scales: Optional[jax.Array] = None  # (N/bn, max_nnz) f32 pow2
    slots: Optional[jax.Array] = None   # (N/bn, max_nnz) int32 tile rows


@dataclasses.dataclass
class PackedExpertProjection:
    """A leading-``E`` stack of per-expert block plans for one MoE
    projection. Experts share ``max_nnz`` (each expert's index row is
    edge-padded, matching ``plan_blocks`` padding semantics — the kernel
    masks on ``counts``), so one stacked plan covers the whole expert
    group even when per-expert densities diverge.

    ``group`` selects the serving path: True (default) executes all E
    experts' matmuls in ONE grouped kernel launch straight off this
    stack; False falls back to E per-expert ``block_sparse`` launches
    through the :meth:`expert` views. ``ragged`` additionally opts
    decode-sized batches into the ragged (routed-tokens-only) kernel
    variant — the same stacked plan drives both."""
    counts: jax.Array          # (E, N/bn)
    indices: jax.Array         # (E, N/bn, max_nnz)
    block: int
    density: float             # mean nonzero-tile fraction over experts
    densities: tuple           # per-expert nonzero-tile fractions
    group: bool = True         # serve via the grouped (one-launch) kernel
    ragged: bool = False       # ragged dispatch for decode-sized batches
    quant: str = "none"        # "none" | "int8" (kept-tile storage)
    tiles: Optional[jax.Array] = None   # (T_total, block, block) int8 —
    #                                     every expert's kept tiles in one
    #                                     stacked array
    scales: Optional[jax.Array] = None  # (E, N/bn, max_nnz) f32 pow2
    slots: Optional[jax.Array] = None   # (E, N/bn, max_nnz) int32 —
    #                                     absolute rows into ``tiles``

    @property
    def n_experts(self) -> int:
        return int(self.counts.shape[0])

    def expert(self, e: int) -> PackedProjection:
        """The expert-``e`` view the block-sparse kernel consumes.
        Quantized stacks hand the *full* tile array to every view — the
        per-expert slot rows are absolute, so each view only ever
        reaches its own expert's tiles."""
        return PackedProjection(counts=self.counts[e],
                                indices=self.indices[e], block=self.block,
                                density=float(self.densities[e]),
                                quant=self.quant, tiles=self.tiles,
                                scales=(None if self.scales is None
                                        else self.scales[e]),
                                slots=(None if self.slots is None
                                       else self.slots[e]))


def _quantize_plan(w2, counts, indices, block: int) -> tuple:
    """Kept-tile int8 storage for one planned 2-D weight: gathered tiles
    quantised with pow2 per-tile scales, plus the (nN, max_nnz) slot and
    scale maps the kernel scalar-prefetches (dead steps edge-clamp with
    the slot map, so their scale entries are the clamped tile's)."""
    from repro.core.quant import quantize_tiles
    tiles = gather_kept_tiles(w2, counts, indices, block, block)
    q, tile_scales = quantize_tiles(tiles)
    slots, _ = plan_slots(counts, np.asarray(indices).shape[-1])
    scales = tile_scales[slots]
    return q, scales, slots


def pack_projection(w, block: int = 128,
                    quant: str = "none") -> Optional[PackedProjection]:
    """Build the kernel's block plan from a pruned weight. Returns None
    when the (2-D-folded) weight doesn't tile evenly. ``quant="int8"``
    additionally compacts the kept tiles into int8 storage riding the
    plan (see :class:`PackedProjection`)."""
    w2 = np.asarray(w).reshape(w.shape[0], -1)
    K, N = w2.shape
    if K % block or N % block:
        return None
    bm = block_mask_from_weight_mask(w2 != 0, block, block)
    counts, indices = plan_blocks(bm)
    p = PackedProjection(counts=counts, indices=indices, block=block,
                        density=float(bm.mean()), quant=quant)
    if quant == "int8":
        q, scales, slots = _quantize_plan(w2, counts, indices, block)
        p.tiles = jnp.asarray(q)
        p.scales = jnp.asarray(scales)
        p.slots = jnp.asarray(slots)
    return p


def pack_expert_projection(w, block: int = 128, group: bool = True,
                           ragged: bool = False, quant: str = "none"
                           ) -> Optional[PackedExpertProjection]:
    """Per-expert block plans for an ``(E, K, ...)`` MoE weight. Each
    expert's 2-D fold is planned independently; index rows are padded to
    the max ``max_nnz`` across experts so the stack is rectangular —
    exactly the layout the grouped kernel's scalar prefetch consumes.
    ``quant="int8"`` concatenates every expert's kept tiles into one
    int8 array with absolute slot rows, so the grouped/ragged kernels
    stream tile storage instead of the dense weight stack."""
    wh = np.asarray(w)
    E = wh.shape[0]
    w2 = wh.reshape(E, wh.shape[1], -1)
    K, N = w2.shape[1], w2.shape[2]
    if K % block or N % block:
        return None
    counts_e, indices_e, densities = [], [], []
    for e in range(E):
        bm = block_mask_from_weight_mask(w2[e] != 0, block, block)
        counts, indices = plan_blocks(bm)
        counts_e.append(counts)
        indices_e.append(indices)
        densities.append(float(bm.mean()))
    from repro.kernels.grouped_block_sparse.ops import stack_expert_plans
    counts, indices = stack_expert_plans(counts_e, indices_e)
    p = PackedExpertProjection(
        counts=jnp.asarray(counts), indices=jnp.asarray(indices),
        block=block, density=float(np.mean(densities)),
        densities=tuple(densities), group=group, ragged=ragged,
        quant=quant)
    if quant == "int8":
        tiles_e, scales_e, slots_e = [], [], []
        off = 0
        for e in range(E):
            q, scales, slots = _quantize_plan(w2[e], counts[e], indices[e],
                                              block)
            tiles_e.append(q)
            scales_e.append(scales)
            slots_e.append(slots + off)
            off += q.shape[0]
        p.tiles = jnp.asarray(np.concatenate(tiles_e))
        p.scales = jnp.asarray(np.stack(scales_e))
        p.slots = jnp.asarray(np.stack(slots_e))
    return p


def quant_plan_bytes(packed: dict, params=None, cfg=None) -> dict:
    """Real storage accounting for the int8 kept-tile plans: per
    projection, the int8 tile bytes + f32 scale-map bytes + int32 plan
    bytes, next to the projection's dense bytes and a bf16 dense
    reference — the ``bytes_after`` evidence the pack report and
    baseline gates consume."""
    per: dict = {}
    dense_lookup = {}
    if params is not None and cfg is not None:
        c = cfg if not cfg.scan_layers else cfg.unrolled()
        for proj in projections(c):
            dense_lookup[proj.key] = tree_get(params, proj.path)
    for key, p in packed.items():
        if getattr(p, "quant", "none") != "int8" or p.tiles is None:
            continue
        tile_bytes = int(p.tiles.size)                       # int8
        scale_bytes = int(p.scales.size) * 4
        plan_bytes = (int(p.counts.size) + int(p.indices.size)
                      + int(p.slots.size)) * 4
        row = {"tile_bytes": tile_bytes, "scale_bytes": scale_bytes,
               "plan_bytes": plan_bytes,
               "bytes": tile_bytes + scale_bytes + plan_bytes}
        w = dense_lookup.get(key)
        if w is not None:
            row["dense_bytes"] = int(w.size) * w.dtype.itemsize
            row["bf16_bytes"] = int(w.size) * 2
        per[f"{key[0]}:{key[1]}"] = row
    total = sum(r["bytes"] for r in per.values())
    dense = sum(r.get("dense_bytes", 0) for r in per.values())
    bf16 = sum(r.get("bf16_bytes", 0) for r in per.values())
    return {"per_projection": per, "total_bytes": total,
            "dense_bytes": dense, "bf16_bytes": bf16,
            "ratio_vs_bf16": (total / bf16 if bf16 else 0.0)}


def pack_model_with_report(params, cfg: ModelConfig, block: int = 128,
                           group_experts: bool = True,
                           ragged_moe: bool = False,
                           quant: str = "none") -> tuple:
    """Returns ``(packed, report)``: ``{(layer, name): PackedProjection}``
    for every tileable projection, plus a summary of what was *not*
    packed (the silent-``None`` paths), so serve-time coverage is
    auditable from the artifact report. ``quant="int8"`` packs kept-tile
    int8 storage into every plan and reports its real byte counts."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    packed: dict = {}
    skipped: list = []
    packed_params = 0
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        n = int(np.prod(w.shape))
        if proj.expert_axis is not None:
            p = pack_expert_projection(w, block, group=group_experts,
                                       ragged=ragged_moe, quant=quant)
        else:
            p = pack_projection(w, block, quant=quant)
        if p is None:
            skipped.append({"layer": proj.layer, "name": proj.name,
                            "params": n, "reason": "non-tileable"})
        else:
            packed[proj.key] = p
            packed_params += n
    n_expert = sum(isinstance(p, PackedExpertProjection)
                   for p in packed.values())
    report = {
        "block": block,
        "group_experts": group_experts,
        "ragged_moe": ragged_moe,
        "quant": quant,
        "n_packed": len(packed),
        "n_expert_packed": n_expert,
        "packed_params": packed_params,
        "n_skipped": len(skipped),
        "skipped_params": sum(s["params"] for s in skipped),
        "skipped": skipped,
        "flop_savings": flop_savings(packed),
    }
    if quant == "int8":
        report["quant_bytes"] = quant_plan_bytes(packed, params, cfg)
    if skipped:
        logging.getLogger(__name__).info(
            "pack_model: skipped %d/%d projections (%d params) — %s",
            len(skipped), len(skipped) + len(packed),
            report["skipped_params"],
            ", ".join(sorted({s["reason"] for s in skipped})))
    return packed, report


def pack_model(params, cfg: ModelConfig, block: int = 128,
               group_experts: bool = True, ragged_moe: bool = False,
               quant: str = "none") -> dict:
    """{(layer, name): PackedProjection | PackedExpertProjection} for
    every tileable projection (MoE expert weights get per-expert plan
    stacks). Skipped (non-tileable) projections are logged; use
    :func:`pack_model_with_report` to get the summary programmatically."""
    packed, _ = pack_model_with_report(params, cfg, block,
                                       group_experts=group_experts,
                                       ragged_moe=ragged_moe, quant=quant)
    return packed


def dequantized_weight(p: PackedProjection, K: int) -> np.ndarray:
    """The fake-quant dense weight a quantized plan encodes: dequantised
    kept tiles scattered into zeros, (K, N) f32. Running the unquantized
    kernel (or a dense matmul) over this is the quantized kernels'
    reference path — bitwise-identical because the scales are powers of
    two."""
    assert p.quant == "int8" and p.tiles is not None
    b = p.block
    counts = np.asarray(p.counts)
    indices = np.asarray(p.indices)
    slots = np.asarray(p.slots)
    tiles = np.asarray(p.tiles, np.float32)
    scales = np.asarray(p.scales)
    w = np.zeros((K, counts.shape[0] * b), np.float32)
    for n in range(counts.shape[0]):
        for s in range(int(counts[n])):
            k = int(indices[n, s])
            w[k * b:(k + 1) * b, n * b:(n + 1) * b] = (
                tiles[slots[n, s]] * scales[n, s])
    return w


def apply_fake_quant(params, cfg: ModelConfig, packed: dict):
    """Replace every quantized projection's weight with its kept-tile
    dequantised round-trip, so the dense forward, the evaluate stage,
    and the unquantized-kernel reference path all see exactly the
    weights the int8 kernels compute with. Non-kept tiles are all-zero
    by construction of the plan, so scattering kept tiles into zeros
    loses nothing."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    for proj in projections(cfg):
        p = packed.get(proj.key)
        if p is None or getattr(p, "quant", "none") != "int8":
            continue
        w = tree_get(params, proj.path)
        if isinstance(p, PackedExpertProjection):
            K = w.shape[1]
            wq = np.stack([dequantized_weight(p.expert(e), K)
                           for e in range(p.n_experts)])
        else:
            K = w.shape[0]
            wq = dequantized_weight(p, K)
        params = tree_set(params, proj.path,
                          jnp.asarray(wq.reshape(w.shape), w.dtype))
    return params


def _use_quant(plan, quant: Optional[str]) -> bool:
    """Resolve the serve-time quant override against the plan: ``None``
    follows the plan's own flag, ``"none"`` forces the dequantized
    reference path, ``"int8"`` requires kept-tile storage."""
    if quant is None:
        return getattr(plan, "quant", "none") == "int8" \
            and plan.tiles is not None
    if quant == "int8":
        if getattr(plan, "quant", "none") != "int8" or plan.tiles is None:
            raise ValueError(
                "quant='int8' requested but the plan carries no int8 "
                "kept-tile storage (pack with PruneRecipe.quant='int8')")
        return True
    return False


def sparse_linear(x, w, packed: PackedProjection, interpret: bool = True,
                  quant: Optional[str] = None):
    """y = x @ w through the block-sparse kernel. x: (..., K); w: (K, N).
    Quantized plans stream their int8 kept tiles instead of ``w``
    (``quant`` overrides the plan flag: "none" forces the dense-weight
    reference path)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm = packed.block
    pad_m = (-M) % bm
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    if _use_quant(packed, quant):
        y = quant_blocksparse_matmul(x2, packed.tiles, packed.counts,
                                     packed.indices, packed.slots,
                                     packed.scales, block_m=bm, block_k=bm,
                                     block_n=bm, interpret=interpret)
    else:
        y = blocksparse_matmul(x2, w.reshape(K, -1), packed.counts,
                               packed.indices, block_m=bm, block_k=bm,
                               block_n=bm, interpret=interpret)
    if pad_m:
        y = y[:M]
    return y.reshape(*lead, -1)


def sparse_apply_mlp(block_params: dict, spec, x, packed_layer: dict,
                     layer: int, interpret: bool = True,
                     quant: Optional[str] = None):
    """Feed-forward through the kernel (gate/up/down as available)."""
    from repro.models.layers import activation
    mlp = block_params["mlp"]
    dtype = x.dtype

    def lin(name, inp):
        w = mlp[name].astype(dtype)
        key = (layer, name)
        if key in packed_layer:
            return sparse_linear(inp, w, packed_layer[key], interpret,
                                 quant=quant)
        return inp @ w

    up = lin("up", x)
    if spec.gated:
        h = activation(spec.act, lin("gate", x)) * up
    else:
        h = activation(spec.act, up)
    return lin("down", h)


def grouped_sparse_linear(xs, ws, packed: PackedExpertProjection,
                          interpret: bool = True, row_live=None,
                          quant: Optional[str] = None):
    """y[e] = x[e] @ w[e] for all experts in ONE grouped kernel launch.
    xs: (E, M, K); ws: (E, K, ...) — trailing dims folded to N. Decode-
    sized slot batches keep the whole M panel resident per expert
    (``block_m=None``); prefill-sized batches fall back to tiling M by
    the plan block. ``row_live`` ((E, M) bool, optional): router
    occupancy — experts/M-blocks with no live row skip compute inside
    the launch (outputs for live rows are bitwise-unchanged)."""
    from repro.kernels.grouped_block_sparse.ops import (
        PANEL_ROWS_MAX, grouped_blocksparse_matmul,
        quant_grouped_blocksparse_matmul)
    E, M, K = xs.shape
    bm = packed.block
    # sublane alignment for the resident panel (16 covers bf16's
    # (16, 128) tile and f32's (8, 128)); plan-block alignment when M
    # is large enough to need tiling
    pad_m = (-M) % (16 if M <= PANEL_ROWS_MAX else bm)
    if pad_m:
        xs = jnp.pad(xs, ((0, 0), (0, pad_m), (0, 0)))
        if row_live is not None:
            row_live = jnp.pad(row_live, ((0, 0), (0, pad_m)))
    block_m = None if M <= PANEL_ROWS_MAX else bm
    if _use_quant(packed, quant):
        y = quant_grouped_blocksparse_matmul(
            xs, packed.tiles, packed.counts, packed.indices, packed.slots,
            packed.scales, block_m=block_m, block_k=bm, block_n=bm,
            interpret=interpret, row_live=row_live)
    else:
        y = grouped_blocksparse_matmul(xs, ws.reshape(E, K, -1),
                                       packed.counts, packed.indices,
                                       block_m=block_m, block_k=bm,
                                       block_n=bm, interpret=interpret,
                                       row_live=row_live)
    if pad_m:
        y = y[:, :M]
    return y


def ragged_sparse_linear(xp, ws, tile_expert,
                         packed: PackedExpertProjection,
                         interpret: bool = True,
                         quant: Optional[str] = None):
    """The ragged expert batch through the stacked tile plan in one
    launch. xp: (M, K) routed tokens packed into tile-aligned per-expert
    segments (M is already a multiple of the ragged tile height — the
    builder's static bound guarantees it); ws: (E, K, ...) — trailing
    dims folded to N; tile_expert: (M / RAGGED_BLOCK_ROWS,) owner map,
    -1 on dead padding tiles (skipped inside the kernel)."""
    from repro.kernels.grouped_block_sparse.ops import (
        RAGGED_BLOCK_ROWS, quant_ragged_blocksparse_matmul,
        ragged_blocksparse_matmul)
    M, K = xp.shape
    E = ws.shape[0]
    bm = packed.block
    assert M % RAGGED_BLOCK_ROWS == 0
    if _use_quant(packed, quant):
        return quant_ragged_blocksparse_matmul(
            xp, packed.tiles, packed.counts, packed.indices, packed.slots,
            packed.scales, tile_expert, block_m=RAGGED_BLOCK_ROWS,
            block_k=bm, block_n=bm, interpret=interpret)
    return ragged_blocksparse_matmul(xp, ws.reshape(E, K, -1),
                                     packed.counts, packed.indices,
                                     tile_expert,
                                     block_m=RAGGED_BLOCK_ROWS,
                                     block_k=bm, block_n=bm,
                                     interpret=interpret)


# Largest token count (B*S at the layer input) served through the
# ragged kernel: decode ticks qualify, prefill-sized batches fall back
# to the grouped capacity-slot launch (whose resident-panel layout wins
# once most experts are occupied anyway). Static per trace — selection
# never retraces on occupancy, only on batch shape like everything else.
RAGGED_TOKENS_MAX = 64


def sparse_apply_moe(block_params: dict, spec, x, packed_layer: dict,
                     layer: int, interpret: bool = True,
                     group_experts: Optional[bool] = None,
                     ragged_moe: Optional[bool] = None,
                     quant: Optional[str] = None):
    """MoE feed-forward with the expert matmuls run through the
    block-sparse kernels under the layer's per-expert plan stacks.
    Routing, dispatch, and combine are ``moe.apply_moe``'s own (shared
    code, no drift); only the expert matmuls are overridden.

    ``group_experts=None`` (default) follows the plans' own ``group``
    flag (set by the pack stage from ``PruneRecipe.group_experts``):
    True executes all E experts in one grouped kernel launch per
    projection, False loops E per-expert launches (the fallback and the
    reference in equivalence tests). The grouped launch is
    occupancy-masked: router counts are threaded in as a live-row mask
    so experts with zero routed tokens (and padded capacity slots) skip
    compute inside the launch.

    ``ragged_moe=None`` (default) follows the plans' ``ragged`` flag
    (from ``PruneRecipe.ragged_moe``). When enabled and the batch is
    decode-sized (``B*S <= RAGGED_TOKENS_MAX``), the capacity-slot
    dispatch is replaced wholesale by the ragged expert batch — only
    routed tokens are packed and the kernel's M-grid covers exactly
    them. All paths are bitwise-identical on served rows."""
    from repro.models.moe import apply_moe
    plans = [p for p in (packed_layer.get((layer, nm))
                         for nm in ("gate", "up", "down"))
             if isinstance(p, PackedExpertProjection)]
    if not plans:
        y, _ = apply_moe(block_params["moe"], spec, x)
        return y
    if group_experts is None:
        group_experts = all(p.group for p in plans)
    if ragged_moe is None:
        ragged_moe = all(p.ragged for p in plans)

    n_tokens = int(x.shape[0]) * int(x.shape[1])
    if ragged_moe and n_tokens <= RAGGED_TOKENS_MAX:
        def expert_ragged_linear(name, xp, ws, tile_expert):
            plan = packed_layer.get((layer, name))
            if isinstance(plan, PackedExpertProjection):
                return ragged_sparse_linear(xp, ws, tile_expert, plan,
                                            interpret, quant=quant)
            # no plan for this projection: per-row expert gather oracle
            from repro.kernels.grouped_block_sparse.ops import \
                RAGGED_BLOCK_ROWS
            row_e = jnp.maximum(
                jnp.repeat(tile_expert, RAGGED_BLOCK_ROWS), 0)
            return jnp.einsum("mk,mkn->mn", xp, ws[row_e])

        y, _ = apply_moe(block_params["moe"], spec, x,
                         expert_ragged_linear=expert_ragged_linear)
        return y

    if group_experts:
        def expert_group_linear(name, xs, ws, row_live):
            plan = packed_layer.get((layer, name))
            if isinstance(plan, PackedExpertProjection):
                return grouped_sparse_linear(xs, ws, plan, interpret,
                                             row_live=row_live,
                                             quant=quant)
            return jnp.einsum("emk,ekn->emn", xs, ws)

        y, _ = apply_moe(block_params["moe"], spec, x,
                         expert_group_linear=expert_group_linear)
        return y

    def expert_linear(name, e, xe, we):
        plan = packed_layer.get((layer, name))
        if isinstance(plan, PackedExpertProjection):
            return sparse_linear(xe, we, plan.expert(e), interpret,
                                 quant=quant)
        return xe @ we

    y, _ = apply_moe(block_params["moe"], spec, x,
                     expert_linear=expert_linear)
    return y


def sparse_apply_ffn(block_params: dict, spec, x, packed: dict,
                     layer: int, interpret: bool = True,
                     group_experts: Optional[bool] = None,
                     ragged_moe: Optional[bool] = None,
                     quant: Optional[str] = None):
    """Feed-forward dispatch for the serving ``mlp_apply`` hook: dense-MLP
    layers go through :func:`sparse_apply_mlp`, MoE layers through
    :func:`sparse_apply_moe` (grouped one-launch expert plans by
    default, per-expert launches with ``group_experts=False``, ragged
    decode dispatch with ``ragged_moe``). ``quant`` picks the weight
    storage the kernels stream: None follows each plan's own flag,
    "int8" requires kept-tile storage, "none" forces the dense-weight
    (dequantized reference) path."""
    from repro.models.specs import MoESpec
    if isinstance(spec, MoESpec):
        return sparse_apply_moe(block_params, spec, x, packed, layer,
                                interpret, group_experts=group_experts,
                                ragged_moe=ragged_moe, quant=quant)
    return sparse_apply_mlp(block_params, spec, x, packed, layer, interpret,
                            quant=quant)


def flop_savings(packed: dict) -> float:
    """Mean fraction of projection FLOPs the kernels skip. Expert plan
    stacks contribute one term per expert (each expert's matmul is a
    full projection's worth of capacity-slot FLOPs), not one term per
    stack — so MoE sweep/Pareto rows report real per-expert savings."""
    if not packed:
        return 0.0
    skipped = []
    for p in packed.values():
        if isinstance(p, PackedExpertProjection):
            skipped.extend(1.0 - d for d in p.densities)
        else:
            skipped.append(1.0 - p.density)
    return float(np.mean(skipped))


# ----------------------------------------------- plan (de)serialization
# The PrunedArtifact persists the block plans so serve startup rehydrates
# them instead of re-deriving from raw weights (no pack_model on the
# serve hot path).

def plans_to_host(packed: dict) -> tuple:
    """``(arrays, meta)``: flat npz-able arrays + JSON-able metadata.
    Expert plan stacks carry ``"expert": true`` plus their per-expert
    densities so :func:`plans_from_host` rebuilds the exact class."""
    arrays: dict = {}
    meta: dict = {}
    for (layer, name), p in packed.items():
        key = f"{layer}:{name}"
        arrays[key + ":counts"] = np.asarray(jax.device_get(p.counts))
        arrays[key + ":indices"] = np.asarray(jax.device_get(p.indices))
        meta[key] = {"block": p.block, "density": p.density}
        if isinstance(p, PackedExpertProjection):
            meta[key]["expert"] = True
            meta[key]["densities"] = list(p.densities)
            meta[key]["group"] = bool(p.group)
            meta[key]["ragged"] = bool(p.ragged)
        if getattr(p, "quant", "none") == "int8" and p.tiles is not None:
            meta[key]["quant"] = p.quant
            arrays[key + ":tiles"] = np.asarray(jax.device_get(p.tiles))
            arrays[key + ":scales"] = np.asarray(jax.device_get(p.scales))
            arrays[key + ":slots"] = np.asarray(jax.device_get(p.slots))
    return arrays, meta


def plans_from_host(arrays: dict, meta: dict) -> dict:
    """Inverse of :func:`plans_to_host`: rebuild the PackedProjection /
    PackedExpertProjection plans the engines consume."""
    packed: dict = {}
    for key, m in meta.items():
        layer, name = key.split(":")
        counts = jnp.asarray(arrays[key + ":counts"])
        indices = jnp.asarray(arrays[key + ":indices"])
        quant_kw: dict = {"quant": str(m.get("quant", "none"))}
        if quant_kw["quant"] == "int8":
            quant_kw["tiles"] = jnp.asarray(arrays[key + ":tiles"])
            quant_kw["scales"] = jnp.asarray(arrays[key + ":scales"])
            quant_kw["slots"] = jnp.asarray(arrays[key + ":slots"])
        if m.get("expert"):
            packed[(int(layer), name)] = PackedExpertProjection(
                counts=counts, indices=indices, block=int(m["block"]),
                density=float(m["density"]),
                densities=tuple(float(d) for d in m["densities"]),
                group=bool(m.get("group", True)),
                ragged=bool(m.get("ragged", False)), **quant_kw)
        else:
            packed[(int(layer), name)] = PackedProjection(
                counts=counts, indices=indices,
                block=int(m["block"]), density=float(m["density"]),
                **quant_kw)
    return packed
