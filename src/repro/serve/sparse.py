"""Block-sparse serving path: run a Mosaic-pruned (``wanda_block`` /
composite) model's projections through the Pallas block-sparse kernel.

``pack_model`` walks the pruned projections once (the PC's Post-Pruning
Optimizer step, Fig. 6 #10), builds the per-projection block plans, and
``sparse_apply_mlp`` executes the feed-forward with zero tiles skipped.
On TPU the skipped tiles are real MXU/HBM savings; on CPU the kernel
runs in interpret mode (tests assert exact agreement with dense).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_get
from repro.core.registry import projections
from repro.kernels.block_sparse.ops import (block_mask_from_weight_mask,
                                            blocksparse_matmul, plan_blocks)
from repro.models.specs import ModelConfig


@dataclasses.dataclass
class PackedProjection:
    counts: jax.Array          # (N/bn,)
    indices: jax.Array         # (N/bn, max_nnz)
    block: int
    density: float             # fraction of nonzero tiles


def pack_projection(w, block: int = 128) -> Optional[PackedProjection]:
    """Build the kernel's block plan from a pruned weight. Returns None
    when the (2-D-folded) weight doesn't tile evenly."""
    w2 = np.asarray(w).reshape(w.shape[0], -1)
    K, N = w2.shape
    if K % block or N % block:
        return None
    bm = block_mask_from_weight_mask(w2 != 0, block, block)
    counts, indices = plan_blocks(bm)
    return PackedProjection(counts=counts, indices=indices, block=block,
                            density=float(bm.mean()))


def pack_model_with_report(params, cfg: ModelConfig,
                           block: int = 128) -> tuple:
    """Returns ``(packed, report)``: ``{(layer, name): PackedProjection}``
    for every tileable projection, plus a summary of what was *not*
    packed (the silent-``None`` paths), so serve-time coverage is
    auditable from the artifact report."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    packed: dict = {}
    skipped: list = []
    packed_params = 0
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        n = int(np.prod(w.shape))
        if proj.expert_axis is not None:
            # expert weights need per-expert plans (future work)
            skipped.append({"layer": proj.layer, "name": proj.name,
                            "params": n, "reason": "expert"})
            continue
        p = pack_projection(w, block)
        if p is None:
            skipped.append({"layer": proj.layer, "name": proj.name,
                            "params": n, "reason": "non-tileable"})
        else:
            packed[proj.key] = p
            packed_params += n
    report = {
        "block": block,
        "n_packed": len(packed),
        "packed_params": packed_params,
        "n_skipped": len(skipped),
        "skipped_params": sum(s["params"] for s in skipped),
        "skipped": skipped,
        "flop_savings": flop_savings(packed),
    }
    if skipped:
        logging.getLogger(__name__).info(
            "pack_model: skipped %d/%d projections (%d params) — %s",
            len(skipped), len(skipped) + len(packed),
            report["skipped_params"],
            ", ".join(sorted({s["reason"] for s in skipped})))
    return packed, report


def pack_model(params, cfg: ModelConfig, block: int = 128) -> dict:
    """{(layer, name): PackedProjection} for every tileable projection.
    Skipped (non-tileable / expert) projections are logged; use
    :func:`pack_model_with_report` to get the summary programmatically."""
    packed, _ = pack_model_with_report(params, cfg, block)
    return packed


def sparse_linear(x, w, packed: PackedProjection, interpret: bool = True):
    """y = x @ w through the block-sparse kernel. x: (..., K); w: (K, N)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm = packed.block
    pad_m = (-M) % bm
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    y = blocksparse_matmul(x2, w.reshape(K, -1), packed.counts,
                           packed.indices, block_m=bm, block_k=bm,
                           block_n=bm, interpret=interpret)
    if pad_m:
        y = y[:M]
    return y.reshape(*lead, -1)


def sparse_apply_mlp(block_params: dict, spec, x, packed_layer: dict,
                     layer: int, interpret: bool = True):
    """Feed-forward through the kernel (gate/up/down as available)."""
    from repro.models.layers import activation
    mlp = block_params["mlp"]
    dtype = x.dtype

    def lin(name, inp):
        w = mlp[name].astype(dtype)
        key = (layer, name)
        if key in packed_layer:
            return sparse_linear(inp, w, packed_layer[key], interpret)
        return inp @ w

    up = lin("up", x)
    if spec.gated:
        h = activation(spec.act, lin("gate", x)) * up
    else:
        h = activation(spec.act, up)
    return lin("down", h)


def flop_savings(packed: dict) -> float:
    """Mean fraction of projection FLOPs the kernel skips."""
    if not packed:
        return 0.0
    return float(np.mean([1.0 - p.density for p in packed.values()]))


# ----------------------------------------------- plan (de)serialization
# The PrunedArtifact persists the block plans so serve startup rehydrates
# them instead of re-deriving from raw weights (no pack_model on the
# serve hot path).

def plans_to_host(packed: dict) -> tuple:
    """``(arrays, meta)``: flat npz-able arrays + JSON-able metadata."""
    arrays: dict = {}
    meta: dict = {}
    for (layer, name), p in packed.items():
        key = f"{layer}:{name}"
        arrays[key + ":counts"] = np.asarray(jax.device_get(p.counts))
        arrays[key + ":indices"] = np.asarray(jax.device_get(p.indices))
        meta[key] = {"block": p.block, "density": p.density}
    return arrays, meta


def plans_from_host(arrays: dict, meta: dict) -> dict:
    """Inverse of :func:`plans_to_host`: rebuild the PackedProjection
    plans the engines consume."""
    packed: dict = {}
    for key, m in meta.items():
        layer, name = key.split(":")
        packed[(int(layer), name)] = PackedProjection(
            counts=jnp.asarray(arrays[key + ":counts"]),
            indices=jnp.asarray(arrays[key + ":indices"]),
            block=int(m["block"]), density=float(m["density"]))
    return packed
