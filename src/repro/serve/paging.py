"""Paged KV pool bookkeeping: block allocator, copy-on-write, prefix
sharing, and the jitted paged prefill/decode step factories.

The device arena is one ``(n_blocks + 1, block_size, n_kv, D)`` array
per layer (``transformer.init_paged_pool``); everything in this module
except the step factories is pure host-side state, mirroring the split
between ``scheduler`` (host) and ``batching`` (device).

- :class:`BlockAllocator` — free-list + per-block refcounts over the
  arena. A block with refcount > 1 is shared (prefix sharing);
  ``ensure_writable`` implements copy-on-write: before a writer touches
  a shared block it gets a private copy (``transformer.copy_pool_block``
  on device) and the share count drops by one.
- :class:`PrefixCache` — deepsparse-session-style cache identity:
  requests carrying the same ``Request.prefix_id`` map their shared
  prompt prefix onto the same refcounted blocks. Only *complete* blocks
  strictly before the last prompt token are shared, so every writer owns
  its tail block and at least one prompt token is always prefilled (the
  sampled-first-token logits come from the writer's own compute).
- ``make_paged_prefill_step`` / ``make_paged_decode_step`` — the jitted
  steps threading per-request block tables through
  ``transformer.forward`` the same way the vector ``cache_index`` is.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.specs import ModelConfig


class OutOfBlocks(RuntimeError):
    """The arena has fewer free blocks than an allocation needs — the
    scheduler holds the request in the queue (admission backpressure)."""


class BlockAllocator:
    """Host-side free-list allocator with per-block refcounts.

    Blocks ``0 .. n_blocks-1`` are allocatable; the arena's extra
    scratch block (index ``n_blocks``) is never handed out — padded
    prefill positions and inactive decode slots write there.
    """

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.scratch = n_blocks          # reserved scratch block id
        self._free = list(range(n_blocks - 1, -1, -1))
        self._refs: dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def refcount(self, block: int) -> int:
        return self._refs.get(block, 0)

    def alloc(self, n: int) -> list:
        """Claim ``n`` fresh blocks (refcount 1 each)."""
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        return out

    def retain(self, blocks) -> None:
        """Add one reference to each block (prefix sharing)."""
        for b in blocks:
            if self._refs.get(b, 0) <= 0:
                raise ValueError(f"retain of unallocated block {b}")
            self._refs[b] += 1

    def release(self, blocks) -> None:
        """Drop one reference; a block at zero returns to the free
        list."""
        for b in blocks:
            r = self._refs.get(b, 0)
            if r <= 0:
                raise ValueError(f"release of unallocated block {b}")
            if r == 1:
                del self._refs[b]
                self._free.append(b)
            else:
                self._refs[b] = r - 1

    def ensure_writable(self, table, j: int, pool,
                        reserve: Optional[int] = None):
        """Copy-on-write: make ``table[j]`` safe for its owner to write.

        If the block is shared (refcount > 1), place a private copy into
        ``reserve`` — a block the owner already claimed at admission
        time — duplicate the contents on device, and drop the shared
        reference. Without a reserve the copy block is allocated here,
        which can raise :class:`OutOfBlocks` against a full arena:
        admission must pre-claim the reserve for any request entering on
        shared blocks so COW can never fail mid-tick. Returns the
        (possibly updated) pool. ``table`` is a mutable host-side
        sequence of physical block ids.
        """
        b = int(table[j])
        if self._refs.get(b, 0) <= 1:
            return pool                 # exclusive (or scratch): no-op
        if reserve is not None:
            fresh = reserve             # refcount 1 since admission
        else:
            (fresh,) = self.alloc(1)
        pool = T.copy_pool_block(pool, b, fresh)
        self.release([b])
        table[j] = fresh
        return pool


class PrefixCache:
    """``prefix_id`` -> shared prompt-prefix blocks (request-level cache
    identity, after deepsparse's ``session_ids``).

    The first request with a given ``prefix_id`` prefills normally and
    ``register``\\ s its full prompt blocks once its prefill completes
    (the blocks' contents are only valid then); later requests whose
    prompt starts with the registered tokens ``match`` those blocks into
    their own block table at +1 refcount and skip prefilling them.
    """

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self._entries: dict[str, tuple] = {}   # id -> (tokens, blocks)

    def __len__(self) -> int:
        return len(self._entries)

    def shareable_tokens(self, prompt) -> int:
        """Tokens coverable by shared full blocks: complete blocks
        strictly before the last prompt token, so the writer always
        prefills >= 1 token into blocks it owns."""
        bs = self.allocator.block_size
        return ((len(prompt) - 1) // bs) * bs

    def match(self, prefix_id: Optional[str], prompt) -> list:
        """Blocks of ``prefix_id`` reusable for ``prompt`` (may be
        ``[]``): the longest block-aligned run of tokens the registered
        entry and this prompt agree on, so requests that diverge
        mid-prompt (same system prefix, different tails) still share the
        common blocks. Caller must map them into a table via
        ``allocator.retain``."""
        if prefix_id is None or prefix_id not in self._entries:
            return []
        tokens, blocks = self._entries[prefix_id]
        limit = min(len(tokens), self.shareable_tokens(prompt))
        same = 0
        for a, b in zip(tokens[:limit], prompt[:limit]):
            if a != b:
                break
            same += 1
        n = (same // self.allocator.block_size) * self.allocator.block_size
        return blocks[:n // self.allocator.block_size]

    def register(self, prefix_id: Optional[str], prompt, table) -> None:
        """After a prefill completes: publish the request's full prompt
        blocks under ``prefix_id``. The cache holds its own reference so
        the blocks outlive the registering request. First writer wins;
        later registrations are no-ops."""
        if prefix_id is None or prefix_id in self._entries:
            return
        n = self.shareable_tokens(prompt)
        if n == 0:
            return
        blocks = [int(b) for b in table[:n // self.allocator.block_size]]
        self.allocator.retain(blocks)
        self._entries[prefix_id] = (tuple(prompt[:n]), blocks)

    def drop_all(self) -> None:
        """Release every cached prefix (end of a serving run)."""
        for _, blocks in self._entries.values():
            self.allocator.release(blocks)
        self._entries.clear()


# ------------------------------------------------------------ jitted steps

def make_paged_prefill_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                            mlp_apply=None):
    """One (chunk of a) B=1 prompt into the paged pool. ``tokens`` is
    right-padded to a bucket; ``n_valid`` masks the padding into the
    scratch block; ``start`` is the chunk's first logical position (> 0
    for later chunks and for requests entering on a shared prefix)."""
    def paged_prefill_step(params, pool, tokens, block_table, start,
                           n_valid):
        logits, pool, _ = T.forward(
            params, cfg, tokens, cache=pool, cache_index=start,
            block_tables=block_table, n_valid=n_valid,
            compute_dtype=compute_dtype, mlp_apply=mlp_apply)
        return logits, pool
    return paged_prefill_step


def make_paged_decode_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                           mlp_apply=None, paged_kernel: bool = False,
                           interpret: bool = True):
    """One token for every slot against the paged pool: the per-slot
    ``lengths`` vector and ``block_tables`` play the role the vector
    ``cache_index`` plays for the contiguous pool. ``paged_kernel``
    routes attention through the fused Pallas paged-attention kernel
    (block tables walked in scalar memory, K/V blocks gathered in-kernel)
    instead of materializing each slot's logical view."""
    def paged_decode_step(params, pool, tokens, lengths, block_tables):
        logits, pool, _ = T.forward(
            params, cfg, tokens, cache=pool, cache_index=lengths,
            block_tables=block_tables, compute_dtype=compute_dtype,
            mlp_apply=mlp_apply, paged_kernel=paged_kernel,
            interpret=interpret)
        return logits[:, -1, :], pool
    return paged_decode_step
