"""Serving: prefill + decode steps and a batched generation engine.

``make_serve_step`` is the artifact the decode/long dry-run shapes lower:
one new token against a KV cache of S_max, cache updated in place.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.specs import ModelConfig


def make_prefill_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    def prefill_step(params, tokens, cache, frontend_embeds=None):
        logits, cache, _ = T.forward(
            params, cfg, tokens, frontend_embeds=frontend_embeds,
            cache=cache, cache_index=jnp.zeros((), jnp.int32),
            compute_dtype=compute_dtype)
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    def serve_step(params, cache, tokens, cache_index):
        """tokens: (B, 1) — decode one token for every sequence."""
        logits, cache, _ = T.forward(
            params, cfg, tokens, cache=cache, cache_index=cache_index,
            compute_dtype=compute_dtype)
        return logits[:, -1, :], cache
    return serve_step


def sample_token(logits: jax.Array, key, temperature: float = 0.0,
                 vocab: Optional[int] = None) -> jax.Array:
    if vocab is not None and vocab < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(mask, -1e30, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1
                                  ).astype(jnp.int32)


class Engine:
    """Minimal batched generation engine over the functional steps."""

    def __init__(self, params, cfg: ModelConfig, max_seq: int,
                 compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.prefill_step = jax.jit(make_prefill_step(cfg, compute_dtype))
        self.serve_step = jax.jit(make_serve_step(cfg, compute_dtype))

    def generate(self, prompt_tokens, n_new: int, temperature: float = 0.0,
                 seed: int = 0):
        """prompt_tokens: (B, S0) -> (B, S0 + n_new)."""
        B, S0 = prompt_tokens.shape
        cache = T.init_cache(self.cfg, B, self.max_seq, self.cache_dtype)
        logits, cache = self.prefill_step(self.params, prompt_tokens, cache)
        key = jax.random.PRNGKey(seed)
        tok = sample_token(logits[:, -1, :], key, temperature, self.cfg.vocab)
        out = [prompt_tokens, tok[:, None]]
        for i in range(n_new - 1):
            key, sub = jax.random.split(key)
            logits, cache = self.serve_step(
                self.params, cache, tok[:, None], jnp.int32(S0 + i))
            tok = sample_token(logits, sub, temperature, self.cfg.vocab)
            out.append(tok[:, None])
        return jnp.concatenate(out, axis=1)
