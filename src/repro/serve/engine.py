"""Serving: prefill + decode steps and a batched generation engine.

``make_serve_step`` is the artifact the decode/long dry-run shapes lower:
one new token against a KV cache of S_max, cache updated in place.

Both step factories accept an optional ``mlp_apply`` override so a
Mosaic-pruned model's feed-forward runs through the Pallas block-sparse
kernel (``repro.serve.sparse``) in the serving hot loop. The
continuous-batching engine lives in ``repro.serve.batching``.

Engines are constructed from a single frozen
:class:`~repro.serve.config.ServeConfig` — the same shape for the
static and continuous engines, in-memory and ``from_artifact``. The
pre-ServeConfig kwarg constructors still work as deprecation shims.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.specs import ModelConfig
from repro.serve.config import ServeConfig


def make_sparse_mlp_apply(packed: dict, interpret: bool = True,
                          group_experts: Optional[bool] = None,
                          ragged_moe: Optional[bool] = None,
                          quant: Optional[str] = None):
    """`mlp_apply` hook routing FFN layers through the block-sparse
    kernels wherever ``packed`` (from ``sparse.pack_model``) has a plan —
    dense MLPs per projection, MoE layers via their per-expert plan
    stacks: one grouped launch for all experts by default
    (``group_experts=None`` follows each plan's own ``group`` flag),
    E per-expert launches with ``group_experts=False``, and — with
    ``ragged_moe`` (None follows each plan's ``ragged`` flag) — the
    ragged routed-tokens-only dispatch at decode batch sizes.

    ``quant`` (from ``ServeConfig.quant``) picks the weight storage the
    kernels stream: None follows each plan's own flag, "int8" requires
    kept-tile int8 storage in the plans (raises up front if absent),
    "none" forces the dequantized reference path."""
    from repro.serve.sparse import sparse_apply_ffn

    if quant == "int8" and not any(
            getattr(p, "quant", "none") == "int8" and p.tiles is not None
            for p in packed.values()):
        raise ValueError(
            "ServeConfig.quant='int8' but no plan carries int8 kept-tile "
            "storage — pack with PruneRecipe.quant='int8' first")

    def mlp_apply(block_params, spec, x, layer):
        return sparse_apply_ffn(block_params, spec, x, packed, layer,
                                interpret=interpret,
                                group_experts=group_experts,
                                ragged_moe=ragged_moe, quant=quant)
    return mlp_apply


def make_prefill_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                      mlp_apply=None):
    def prefill_step(params, tokens, cache, frontend_embeds=None):
        logits, cache, _ = T.forward(
            params, cfg, tokens, frontend_embeds=frontend_embeds,
            cache=cache, cache_index=jnp.zeros((), jnp.int32),
            compute_dtype=compute_dtype, mlp_apply=mlp_apply)
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                    mlp_apply=None):
    def serve_step(params, cache, tokens, cache_index):
        """tokens: (B, 1) — decode one token for every sequence.
        cache_index: scalar, or (B,) per-slot lengths (continuous)."""
        logits, cache, _ = T.forward(
            params, cfg, tokens, cache=cache, cache_index=cache_index,
            compute_dtype=compute_dtype, mlp_apply=mlp_apply)
        return logits[:, -1, :], cache
    return serve_step


def sample_token(logits: jax.Array, key, temperature: float = 0.0,
                 vocab: Optional[int] = None) -> jax.Array:
    if vocab is not None and vocab < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(mask, -1e30, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1
                                  ).astype(jnp.int32)


def sample_tokens(logits: jax.Array, keys: jax.Array, temps: jax.Array,
                  vocab: Optional[int] = None) -> jax.Array:
    """Per-row sampling with *traced* per-row temperatures and keys.

    logits: (B, V); keys: (B, 2) uint32 PRNG keys; temps: (B,) float32.
    Rows with ``temps <= 0`` are greedy (argmax); positive rows sample
    their own categorical stream. Because temperature is a traced
    vector — not a static argument — mixed-temperature batches never
    retrace the decode step.
    """
    if vocab is not None and vocab < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(mask, -1e30, logits)
    # branch-free on purpose: a lax.cond here stalls XLA CPU's async
    # dispatch pipeline and serializes the whole decode burst (~10x)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temps > 0, temps, 1.0).astype(logits.dtype)
    drawn = jax.vmap(jax.random.categorical)(keys, logits / safe_t[:, None])
    return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)


def request_key(seed: Optional[int], uid: int, run_seed: int) -> jax.Array:
    """The request's base sampling key: its own ``seed`` when set (the
    stream is then independent of batch composition and reproducible
    across runs), else a per-uid fold of the engine-run seed."""
    if seed is not None:
        return jax.random.PRNGKey(seed)
    return jax.random.fold_in(jax.random.PRNGKey(run_seed), uid)


def _legacy_serve_config(engine: str, max_slots, max_seq, compute_dtype,
                         cache_dtype, interpret, prefill_multiple,
                         group_experts) -> ServeConfig:
    """Assemble a ServeConfig from pre-redesign kwargs (deprecated)."""
    warnings.warn(
        f"{engine}(..., max_seq=, compute_dtype=, ...) kwargs are "
        "deprecated; pass a repro.serve.config.ServeConfig",
        DeprecationWarning, stacklevel=3)
    kw = dict(max_seq=max_seq, compute_dtype=compute_dtype,
              cache_dtype=cache_dtype, interpret=interpret,
              group_experts=group_experts)
    if max_slots is not None:
        kw["max_slots"] = max_slots
    if prefill_multiple is not None:
        kw["prefill_multiple"] = prefill_multiple
    defaults = ServeConfig()
    return ServeConfig(**{k: (v if v is not None
                              else getattr(defaults, k))
                          for k, v in kw.items()})


class Engine:
    """Minimal static-batch generation engine over the functional steps.

    ``packed`` (from ``sparse.pack_model``) routes the MLP projections
    through the block-sparse kernel — the Mosaic fast path.
    """

    def __init__(self, params, cfg: ModelConfig, serve=None,
                 max_seq: Optional[int] = None,
                 compute_dtype=None, cache_dtype=None,
                 packed: Optional[dict] = None,
                 interpret: Optional[bool] = None,
                 group_experts: Optional[bool] = None):
        if isinstance(serve, int):      # legacy positional max_seq
            serve, max_seq = None, serve
        if serve is None:
            serve = _legacy_serve_config(
                "Engine", None, max_seq, compute_dtype, cache_dtype,
                interpret, None, group_experts)
        self.serve = serve
        self.params = params
        self.cfg = cfg
        self.max_seq = serve.max_seq
        self.cache_dtype = serve.cache_dtype
        mlp_apply = (make_sparse_mlp_apply(packed, serve.interpret,
                                           serve.group_experts,
                                           serve.ragged_moe, serve.quant)
                     if packed else None)
        self.prefill_step = jax.jit(
            make_prefill_step(cfg, serve.compute_dtype, mlp_apply))
        self.serve_step = jax.jit(
            make_serve_step(cfg, serve.compute_dtype, mlp_apply))

    @classmethod
    def from_artifact(cls, artifact, serve=None, *, sparse: bool = True,
                      **kw) -> "Engine":
        """Serve a loaded :class:`~repro.core.artifact.PrunedArtifact`
        directly: params, pruned config, and (with ``sparse=True``) the
        saved block plans — no ``pack_model`` at startup. Rehydrated
        expert plan stacks keep their saved ``group`` flag, so MoE
        bundles packed for the grouped kernel serve through the
        one-launch path with zero repacking. ``serve`` is a
        :class:`ServeConfig` (an int is the deprecated ``max_seq``)."""
        packed = artifact.packed if sparse else None
        return cls(artifact.params, artifact.cfg, serve,
                   packed=packed or None, **kw)

    def generate(self, prompt_tokens, n_new: int, temperature: float = 0.0,
                 seed: int = 0):
        """prompt_tokens: (B, S0) -> (B, S0 + n_new)."""
        B, S0 = prompt_tokens.shape
        cache = T.init_cache(self.cfg, B, self.max_seq, self.cache_dtype)
        logits, cache = self.prefill_step(self.params, prompt_tokens, cache)
        key = jax.random.PRNGKey(seed)
        tok = sample_token(logits[:, -1, :], key, temperature, self.cfg.vocab)
        out = [prompt_tokens, tok[:, None]]
        for i in range(n_new - 1):
            key, sub = jax.random.split(key)
            logits, cache = self.serve_step(
                self.params, cache, tok[:, None], jnp.int32(S0 + i))
            tok = sample_token(logits, sub, temperature, self.cfg.vocab)
            out.append(tok[:, None])
        return jnp.concatenate(out, axis=1)
