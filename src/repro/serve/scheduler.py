"""Request scheduler for the continuous-batching engine.

Admission into a fixed pool of KV-cache slots: a request waits in the
arrival queue until a slot frees (and, on the paged pool, until the
block allocator can cover it — admission backpressure), moves to the
``prefilling`` state while its prompt enters the cache (possibly one
chunk per tick, interleaved with decode), then decodes one token per
engine tick alongside every other active slot. Finished sequences
(EOS / per-request token budget / cache full) release their slot
immediately, so requests of different lengths flow through the batch
without ever recompiling the decode step.

*Which* waiting request is admitted next is a pluggable
:class:`~repro.serve.policies.SchedulerPolicy` (``fifo`` default —
strict arrival order, PR 6 semantics — plus ``priority`` and ``slo``);
the scheduler owns slots and lifecycle, the policy owns queue order.
Rejections are first-class: every dropped request becomes a
:class:`Rejection` with a structured reason instead of a bare entry in
a list nothing reads.

Pure host-side bookkeeping — no jax in this module. The engine
(``repro.serve.batching``) owns the device arrays and calls
``admissions`` / ``started`` / ``decoded`` around its jitted steps.
The optional ``on_token`` / ``on_finish`` / ``on_reject`` callbacks
fire from those same host-side calls — the streaming gateway
(``repro.serve.gateway``) hangs its per-request channels off them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.serve.policies import SchedulerPolicy, make_policy


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is seconds on the engine's
    workload clock (0 = available immediately).

    ``prefix_id`` is deepsparse-session-style cache identity: requests
    sharing a ``prefix_id`` (and the prompt tokens under it) share the
    prompt prefix's KV blocks on the paged pool. Sampling knobs ride on
    the request — ``temperature``/``seed`` of ``None`` fall back to the
    engine-run defaults — so mixed-temperature batches decode in one
    jitted step. ``priority`` (higher = sooner) and ``deadline_ms``
    (latency SLO relative to arrival) only matter under the
    ``priority`` / ``slo`` scheduler policies; ``fifo`` ignores both.
    """
    uid: int
    prompt: list
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    prefix_id: Optional[str] = None
    temperature: Optional[float] = None
    seed: Optional[int] = None
    priority: int = 0
    deadline_ms: Optional[float] = None


@dataclasses.dataclass
class Slot:
    """An active sequence bound to a KV-pool slot."""
    index: int
    request: Request
    length: int = 0             # tokens currently in the slot's cache
    last_token: int = 0         # next decode input (last sampled token)
    generated: list = dataclasses.field(default_factory=list)
    admitted_at: float = 0.0
    first_token_at: float = 0.0
    prefilled: int = 0          # prompt tokens already in the cache
    #                             (starts > 0 on a shared prefix)
    shared_blocks: int = 0      # prompt blocks mapped from a prefix hit


@dataclasses.dataclass
class Finished:
    request: Request
    tokens: list                # generated tokens (includes EOS if hit)
    reason: str                 # "eos" | "length" | "cache_full"
    admitted_at: float
    first_token_at: float
    finished_at: float
    prompt_blocks_shared: int = 0   # paged: prefix-cache block hits


@dataclasses.dataclass
class Rejection:
    """A dropped request plus the structured reason it was dropped."""
    request: Request
    reason: str     # "prompt_too_long" | "insufficient_blocks"
    at: float = 0.0


class Scheduler:
    def __init__(self, max_slots: int, max_seq: int,
                 policy: Optional[SchedulerPolicy | str] = None,
                 on_token: Optional[Callable] = None,
                 on_finish: Optional[Callable] = None,
                 on_reject: Optional[Callable] = None):
        self.max_slots = max_slots
        self.max_seq = max_seq
        if policy is None or isinstance(policy, str):
            policy = make_policy(policy or "fifo")
        self.policy = policy
        self.prefilling: dict[int, Slot] = {}       # index -> admitted slot
        self.slots: dict[int, Slot] = {}            # index -> decoding slot
        self.free: list[int] = list(range(max_slots - 1, -1, -1))
        self.finished: list[Finished] = []
        self.rejected: list[Rejection] = []
        self.on_token = on_token        # (slot, token, now)
        self.on_finish = on_finish      # (Finished)
        self.on_reject = on_reject      # (Rejection)

    # ------------------------------------------------------------ intake

    def submit(self, request: Request) -> None:
        if len(request.prompt) + 1 > self.max_seq:
            # can't fit prompt + one generated token
            self.reject(request, "prompt_too_long", request.arrival)
        else:
            self.policy.push(request)

    def reject(self, request: Request, reason: str,
               now: float = 0.0) -> None:
        rej = Rejection(request=request, reason=reason, at=now)
        self.rejected.append(rej)
        if self.on_reject is not None:
            self.on_reject(rej)

    def admissions(self, now: float = 0.0, can_admit=None) -> list[Slot]:
        """Pop arrived requests (in the policy's order) into free slots;
        each returned ``Slot`` enters the ``prefilling`` state — the
        engine feeds its prompt into the cache (in one shot or chunk by
        chunk) and then calls ``started``. ``can_admit(request)`` is the
        engine's resource gate (paged-pool block availability); a False
        holds the policy's head — backpressure stalls, it never
        reorders around resources."""
        out = []
        while self.free:
            req = self.policy.head(now)
            if req is None:
                break
            if can_admit is not None and not can_admit(req):
                break
            self.policy.pop()
            slot = Slot(index=self.free.pop(), request=req, admitted_at=now)
            self.prefilling[slot.index] = slot
            out.append(slot)
        return out

    # ------------------------------------------------------- engine hooks

    def started(self, slot: Slot, first_token: int, now: float = 0.0) -> None:
        """Prefill done: prompt is in the cache, first token sampled."""
        self.prefilling.pop(slot.index, None)
        self.slots[slot.index] = slot
        slot.length = len(slot.request.prompt)
        slot.prefilled = slot.length
        slot.last_token = int(first_token)
        slot.generated = [int(first_token)]
        slot.first_token_at = now
        if self.on_token is not None:
            self.on_token(slot, int(first_token), now)
        self._maybe_finish(slot, now)

    def decoded(self, tokens: dict, now: float = 0.0) -> None:
        """One decode tick: ``tokens[slot_index]`` is the token sampled
        for that slot. The decode step wrote the *previous* token's KV at
        position ``length``, so every active slot grows by one."""
        for idx, tok in tokens.items():
            slot = self.slots.get(idx)
            if slot is None:
                continue
            slot.length += 1
            slot.last_token = int(tok)
            slot.generated.append(int(tok))
            if self.on_token is not None:
                self.on_token(slot, int(tok), now)
            self._maybe_finish(slot, now)

    def _maybe_finish(self, slot: Slot, now: float) -> None:
        req = slot.request
        if req.eos_id is not None and slot.generated[-1] == req.eos_id:
            reason = "eos"
        elif len(slot.generated) >= req.max_new_tokens:
            reason = "length"
        elif slot.length >= self.max_seq:
            reason = "cache_full"   # no room to write the next token's KV
        else:
            return
        fin = Finished(
            request=req, tokens=slot.generated, reason=reason,
            admitted_at=slot.admitted_at, first_token_at=slot.first_token_at,
            finished_at=now, prompt_blocks_shared=slot.shared_blocks)
        self.finished.append(fin)
        del self.slots[slot.index]
        self.free.append(slot.index)
        if self.on_finish is not None:
            self.on_finish(fin)

    # ------------------------------------------------------------- state

    @property
    def queue(self) -> SchedulerPolicy:
        """The policy's waiting queue (len / truthiness view)."""
        return self.policy

    def head(self, now: float = 0.0) -> Optional[Request]:
        """The next admissible request in policy order, if arrived."""
        return self.policy.head(now)

    def pop_head(self) -> Request:
        """Remove the request the last ``head()`` call returned."""
        return self.policy.pop()

    def next_arrival(self) -> Optional[float]:
        return self.policy.next_arrival()

    def active(self) -> list[Slot]:
        return sorted(self.slots.values(), key=lambda s: s.index)

    def has_work(self) -> bool:
        return bool(self.slots or self.prefilling or len(self.policy))

    def utilization(self) -> float:
        return len(self.slots) / self.max_slots

    def concurrency(self) -> int:
        """Sequences currently holding cache resources."""
        return len(self.slots) + len(self.prefilling)
