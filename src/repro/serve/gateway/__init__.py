"""Serving front door: async streaming gateway over the continuous
engine.

- :mod:`repro.serve.gateway.protocol` — the typed wire schema: request
  fields, validation, and the ndjson stream events.
- :mod:`repro.serve.gateway.server` — the asyncio front door:
  ``EngineBridge`` runs the engine tick loop in a background thread
  with a thread-safe submission queue and per-request async token
  channels; ``Gateway`` speaks minimal HTTP/1.1 on top (``POST
  /generate`` streaming, ``GET /metrics``, ``GET /healthz``).
- :mod:`repro.serve.gateway.placement` — artifact-driven pool sizing:
  a worker reads a bundle's ``report.json`` + ``config.json`` (never
  the weights) to size its slot/block pools for its memory budget.
"""
from repro.serve.gateway.placement import Placement, plan_placement
from repro.serve.gateway.protocol import (GenerateRequest, ProtocolError,
                                          parse_request)
from repro.serve.gateway.server import EngineBridge, Gateway

__all__ = ["GenerateRequest", "ProtocolError", "parse_request",
           "EngineBridge", "Gateway", "Placement", "plan_placement"]
