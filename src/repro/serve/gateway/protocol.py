"""Gateway wire schema: typed request/response + ndjson stream events.

A generation request is one JSON object (the body of ``POST
/generate``). Either ``prompt`` (text, encoded by the placeholder
byte-level tokenizer — the repo has no learned tokenizer) or ``tokens``
(explicit token ids) must be present, never both. Everything else is
optional with engine defaults; ``priority`` and ``deadline_ms`` only
matter under the ``priority`` / ``slo`` scheduler policies.

The response is a newline-delimited JSON event stream (one object per
line, ``Content-Type: application/x-ndjson``):

- ``{"event": "token", "uid", "index", "token"}`` — one generated
  token, in order (tokens surface at decode-burst boundaries, so
  several lines may arrive at once).
- ``{"event": "done", "uid", "tokens", "finish_reason", "metrics"}`` —
  terminal; ``metrics`` carries the request's per-stage latencies
  (``queue_ms`` / ``prefill_ms`` / ``decode_ms`` / ``total_ms``).
- ``{"event": "rejected", "uid", "reason"}`` — terminal; ``reason`` is
  the scheduler's structured rejection reason
  (``prompt_too_long`` | ``insufficient_blocks``).
- ``{"event": "error", "error"}`` — malformed request (HTTP 400).

With ``"stream": false`` the gateway buffers and returns only the
terminal event as a plain JSON response. Parsing failures raise
:class:`ProtocolError` (mapped to HTTP 400 with an ``error`` event).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serve.metrics import stage_latencies_ms
from repro.serve.scheduler import Finished, Rejection, Request


class ProtocolError(ValueError):
    """Malformed gateway request (maps to HTTP 400)."""


@dataclasses.dataclass(frozen=True)
class GenerateRequest:
    """The ``POST /generate`` body, validated."""
    prompt: Optional[str] = None        # text (placeholder byte tokenizer)
    tokens: Optional[tuple] = None      # explicit token ids
    max_new_tokens: int = 16
    temperature: Optional[float] = None  # None = engine default
    seed: Optional[int] = None           # None = engine-run stream
    priority: int = 0                    # higher = sooner ("priority")
    prefix_id: Optional[str] = None      # paged prefix-sharing identity
    deadline_ms: Optional[float] = None  # latency SLO ("slo" policy)
    eos_id: Optional[int] = None         # stop token
    stream: bool = True                  # ndjson stream vs buffered JSON


# the wire fields, in schema order (docs-sync test anchors on this)
REQUEST_FIELDS = tuple(f.name for f in dataclasses.fields(GenerateRequest))


def encode_text(prompt: str, vocab: int) -> list:
    """Placeholder byte-level tokenizer: UTF-8 bytes folded into the
    model's vocab. Deterministic and reversible enough for smoke
    traffic; swap in a real tokenizer for a real deployment."""
    return [b % vocab for b in prompt.encode("utf-8")]


def parse_request(body: dict, vocab: int) -> GenerateRequest:
    """Validate one JSON body into a :class:`GenerateRequest`."""
    if not isinstance(body, dict):
        raise ProtocolError("request body must be a JSON object")
    unknown = set(body) - set(REQUEST_FIELDS)
    if unknown:
        raise ProtocolError(f"unknown fields: {sorted(unknown)}")
    prompt = body.get("prompt")
    tokens = body.get("tokens")
    if (prompt is None) == (tokens is None):
        raise ProtocolError("exactly one of 'prompt' (text) or "
                            "'tokens' (ids) is required")
    if prompt is not None and not isinstance(prompt, str):
        raise ProtocolError("'prompt' must be a string")
    if tokens is not None:
        if (not isinstance(tokens, list) or not tokens
                or not all(isinstance(t, int) for t in tokens)):
            raise ProtocolError("'tokens' must be a non-empty list of ints")
        if not all(0 <= t < vocab for t in tokens):
            raise ProtocolError(f"token ids must be in [0, {vocab})")
        tokens = tuple(tokens)
    max_new = body.get("max_new_tokens", 16)
    if not isinstance(max_new, int) or max_new < 1:
        raise ProtocolError("'max_new_tokens' must be a positive int")
    for name, typ in (("temperature", (int, float)), ("seed", int),
                      ("priority", int), ("deadline_ms", (int, float)),
                      ("eos_id", int), ("prefix_id", str)):
        val = body.get(name)
        if val is not None and not isinstance(val, typ):
            raise ProtocolError(f"'{name}' must be {typ}")
    if body.get("deadline_ms") is not None and body["deadline_ms"] <= 0:
        raise ProtocolError("'deadline_ms' must be positive")
    return GenerateRequest(
        prompt=prompt, tokens=tokens, max_new_tokens=max_new,
        temperature=body.get("temperature"), seed=body.get("seed"),
        priority=body.get("priority", 0),
        prefix_id=body.get("prefix_id"),
        deadline_ms=body.get("deadline_ms"),
        eos_id=body.get("eos_id"),
        stream=bool(body.get("stream", True)))


def to_engine_request(greq: GenerateRequest, uid: int,
                      vocab: int) -> Request:
    """Bind a validated wire request to an engine scheduler Request.
    ``arrival`` is stamped by the engine feed at intake."""
    toks = (list(greq.tokens) if greq.tokens is not None
            else encode_text(greq.prompt, vocab))
    if not toks:
        raise ProtocolError("'prompt' encoded to zero tokens")
    return Request(
        uid=uid, prompt=toks, max_new_tokens=greq.max_new_tokens,
        eos_id=greq.eos_id, prefix_id=greq.prefix_id,
        temperature=greq.temperature, seed=greq.seed,
        priority=greq.priority, deadline_ms=greq.deadline_ms)


# ------------------------------------------------------------- events

def token_event(uid: int, index: int, token: int) -> dict:
    return {"event": "token", "uid": uid, "index": index, "token": token}


def done_event(fin: Finished) -> dict:
    return {"event": "done", "uid": fin.request.uid,
            "tokens": list(fin.tokens), "finish_reason": fin.reason,
            "prompt_blocks_shared": fin.prompt_blocks_shared,
            "metrics": {k: round(v, 3)
                        for k, v in stage_latencies_ms(fin).items()}}


def rejected_event(rej: Rejection) -> dict:
    return {"event": "rejected", "uid": rej.request.uid,
            "reason": rej.reason}


def error_event(message: str, uid: Optional[int] = None) -> dict:
    ev = {"event": "error", "error": message}
    if uid is not None:
        ev["uid"] = uid
    return ev
