"""Asyncio front door over the continuous engine.

Two layers:

- :class:`EngineBridge` — runs ``ContinuousEngine.serve_forever`` in a
  background thread and marshals its events back onto the asyncio
  loop. Submissions go through a thread-safe ``queue.Queue``; each
  request gets its own ``asyncio.Queue`` token channel, fed via
  ``loop.call_soon_threadsafe`` so the engine thread never touches
  asyncio state directly. Because the bridge drives the exact same
  tick loop as ``ContinuousEngine.run`` (the feed seam in
  ``repro.serve.batching``), streamed outputs are token-identical to
  driving the engine directly.
- :class:`Gateway` — minimal HTTP/1.1 on ``asyncio.start_server`` (no
  external web framework): ``POST /generate`` streams ndjson events
  (see :mod:`repro.serve.gateway.protocol`), ``GET /metrics`` dumps
  the engine's :class:`~repro.serve.metrics.MetricsRegistry` summary
  plus live request counters, ``GET /healthz`` reports engine-thread
  liveness. Responses are close-delimited (``Connection: close``).

``port=0`` binds an ephemeral port (tests); ``Gateway.port`` reports
the bound port after ``start()``.
"""
from __future__ import annotations

import asyncio
import itertools
import json
import queue as queue_mod
import threading
from typing import Optional

from repro.serve.gateway import protocol as P
from repro.serve.gateway.protocol import (GenerateRequest, ProtocolError,
                                          parse_request)


class EngineBridge:
    """Owns the engine thread and the per-request async token channels.

    All public methods must be called from the asyncio event-loop
    thread (the channels dict is loop-confined); only ``_emit`` runs on
    the engine thread, and it crosses back via
    ``call_soon_threadsafe``.
    """

    def __init__(self, engine, temperature: float = 0.0, seed: int = 0,
                 max_burst: int = 8, poll_s: float = 0.002):
        self.engine = engine
        self.temperature = temperature
        self.seed = seed
        self.max_burst = max_burst
        self.poll_s = poll_s
        self.inbox: queue_mod.Queue = queue_mod.Queue()
        self.stop_event = threading.Event()
        self._channels: dict[int, asyncio.Queue] = {}
        self._uids = itertools.count()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._result = None            # (finished, ServeStats) after join
        self._error: Optional[BaseException] = None

    # ---------------------------------------------------------- lifecycle

    def start(self, loop: Optional[asyncio.AbstractEventLoop] = None):
        self._loop = loop or asyncio.get_running_loop()
        self._thread = threading.Thread(target=self._run,
                                        name="engine-tick-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def _run(self):
        try:
            self._result = self.engine.serve_forever(
                self.inbox, self._emit, stop=self.stop_event,
                temperature=self.temperature, seed=self.seed,
                max_burst=self.max_burst, poll_s=self.poll_s)
        except BaseException as exc:  # noqa: BLE001 — surfaced to clients
            self._error = exc
            if self._loop is not None:
                self._loop.call_soon_threadsafe(self._fail_all, exc)

    def shutdown(self):
        """Stop intake, drain in-flight work, join the engine thread.
        Returns ``(finished, stats)`` exactly like ``engine.run``."""
        self.stop_event.set()
        if self._thread is not None:
            self._thread.join()
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def alive(self) -> bool:
        return (self._thread is not None and self._thread.is_alive()
                and self._error is None)

    def stats(self) -> dict:
        """JSON-safe stats: the final ``ServeStats`` dump after
        shutdown, a live counter snapshot while serving."""
        if self._result is not None:
            return self._result[1].to_dict()
        counters = self.engine.metrics.counters
        return {"live": True,
                "finished": int(counters.get("requests.finished", 0)),
                "rejected": int(sum(v for k, v in counters.items()
                                    if k.startswith("requests.rejected."))),
                "reject_reasons": {
                    k.removeprefix("requests.rejected."): int(v)
                    for k, v in counters.items()
                    if k.startswith("requests.rejected.")},
                "in_flight": len(self._channels)}

    # ------------------------------------------------------------- intake

    def submit(self, greq: GenerateRequest) -> tuple[int, asyncio.Queue]:
        """Register a validated request; returns ``(uid, channel)``.
        The channel yields protocol event dicts ending with a terminal
        ``done`` / ``rejected`` / ``error`` event."""
        if not self.alive:
            raise RuntimeError("engine thread is not running")
        uid = next(self._uids)
        req = P.to_engine_request(greq, uid, self.engine.cfg.vocab)
        channel: asyncio.Queue = asyncio.Queue()
        self._channels[uid] = channel
        self.inbox.put(req)
        return uid, channel

    async def events(self, uid: int, channel: asyncio.Queue):
        """Async-iterate the request's events until its terminal one."""
        while True:
            ev = await channel.get()
            yield ev
            if ev["event"] in ("done", "rejected", "error"):
                return

    # ----------------------------------------- engine thread -> event loop

    def _emit(self, event: tuple) -> None:
        """Engine-thread callback: marshal one event to its channel."""
        kind = event[0]
        if kind == "token":
            _, uid, index, token = event
            self._loop.call_soon_threadsafe(
                self._deliver, uid, P.token_event(uid, index, token), False)
        elif kind == "finished":
            fin = event[1]
            self._loop.call_soon_threadsafe(
                self._deliver, fin.request.uid, P.done_event(fin), True)
        elif kind == "rejected":
            rej = event[1]
            self._loop.call_soon_threadsafe(
                self._deliver, rej.request.uid, P.rejected_event(rej), True)

    def _deliver(self, uid: int, ev: dict, terminal: bool) -> None:
        channel = (self._channels.pop(uid, None) if terminal
                   else self._channels.get(uid))
        if channel is not None:
            channel.put_nowait(ev)

    def _fail_all(self, exc: BaseException) -> None:
        """Engine thread died: every in-flight channel gets a terminal
        wire ``error`` event (tagged with its uid) instead of waiting
        forever; ``alive`` is already False, so ``/healthz`` flips to
        503 and new submits are refused."""
        for uid, channel in self._channels.items():
            channel.put_nowait(
                P.error_event(f"engine died: {exc!r}", uid=uid))
        self._channels.clear()


class Gateway:
    """The HTTP front door. ``await start()`` binds the socket and
    spins up the engine thread; ``await close()`` tears both down and
    returns the engine's ``(finished, stats)``."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 temperature: float = 0.0, seed: int = 0,
                 max_burst: int = 8):
        self.bridge = EngineBridge(engine, temperature=temperature,
                                   seed=seed, max_burst=max_burst)
        self.engine = engine
        self.host = host
        self.port = port            # 0 = ephemeral; real port after start
        self._server: Optional[asyncio.base_events.Server] = None

    # ---------------------------------------------------------- lifecycle

    async def start(self) -> "Gateway":
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self.bridge.start(asyncio.get_running_loop())
        return self

    async def close(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        # joining the engine thread blocks; keep the loop responsive
        return await asyncio.get_running_loop().run_in_executor(
            None, self.bridge.shutdown)

    async def serve_forever(self):
        async with self._server:
            await self._server.serve_forever()

    # --------------------------------------------------------------- http

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                head = await asyncio.wait_for(
                    reader.readuntil(b"\r\n\r\n"), timeout=30.0)
            except (asyncio.IncompleteReadError, asyncio.TimeoutError,
                    asyncio.LimitOverrunError):
                return
            method, path, headers = self._parse_head(head)
            body = b""
            length = int(headers.get("content-length", "0"))
            if length:
                body = await reader.readexactly(length)

            if method == "GET" and path == "/healthz":
                status = "ok" if self.bridge.alive else "dead"
                await self._json(writer, 200 if status == "ok" else 503,
                                 {"status": status})
            elif method == "GET" and path == "/metrics":
                await self._json(writer, 200, {
                    "metrics": self.engine.metrics.summary(),
                    "stats": self.bridge.stats()})
            elif method == "POST" and path == "/generate":
                await self._generate(writer, body)
            else:
                await self._json(writer, 404,
                                 P.error_event(f"no route {method} {path}"))
        except ConnectionError:
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    @staticmethod
    def _parse_head(head: bytes) -> tuple[str, str, dict]:
        lines = head.decode("latin-1").split("\r\n")
        method, path, _ = lines[0].split(" ", 2)
        headers = {}
        for line in lines[1:]:
            if ":" in line:
                key, val = line.split(":", 1)
                headers[key.strip().lower()] = val.strip()
        return method, path, headers

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            greq = parse_request(json.loads(body.decode("utf-8")),
                                 self.engine.cfg.vocab)
            uid, channel = self.bridge.submit(greq)
        except (ProtocolError, UnicodeDecodeError,
                json.JSONDecodeError) as exc:
            await self._json(writer, 400, P.error_event(str(exc)))
            return
        except RuntimeError as exc:
            await self._json(writer, 503, P.error_event(str(exc)))
            return
        if not greq.stream:
            last = None
            async for ev in self.bridge.events(uid, channel):
                last = ev
            # a terminal error event (engine death mid-request) must not
            # masquerade as a successful completion on the buffered path
            status = 503 if last["event"] == "error" else 200
            await self._json(writer, status, last)
            return
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Connection: close\r\n\r\n")
        async for ev in self.bridge.events(uid, channel):
            writer.write(json.dumps(ev).encode("utf-8") + b"\n")
            await writer.drain()

    @staticmethod
    async def _json(writer: asyncio.StreamWriter, status: int,
                    obj: dict) -> None:
        reasons = {200: "OK", 400: "Bad Request", 404: "Not Found",
                   503: "Service Unavailable"}
        payload = json.dumps(obj).encode("utf-8") + b"\n"
        writer.write(
            f"HTTP/1.1 {status} {reasons.get(status, 'OK')}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n"
            f"Connection: close\r\n\r\n".encode("latin-1") + payload)
        await writer.drain()
