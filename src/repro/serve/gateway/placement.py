"""Artifact-driven pool sizing: size a worker from the bundle's report.

A serving worker deciding how many slots / KV blocks it can afford
needs two numbers: how many bytes the pruned weights occupy and how
many bytes one token of KV cache costs. Both are derivable from a
saved bundle's ``report.json`` (``bytes_after``, ``params_*``) and
``config.json`` (the post-pruning :class:`ModelConfig`) — so placement
reads *only* those two JSON files and never touches the weights. That
makes the sizing decision cheap enough to run per-candidate in a
placement loop (which artifact fits which worker) before any worker
commits to a multi-second weight load.

``plan_placement`` turns an artifact directory plus a memory budget
into a :class:`Placement`: the derived byte accounting and a ready
:class:`~repro.serve.config.ServeConfig` with ``max_slots`` /
``n_blocks`` sized so weights + KV arena + headroom fit the budget.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional

import jax.numpy as jnp

from repro.models.specs import AttentionSpec, config_from_dict
from repro.serve.config import ServeConfig


def kv_bytes_per_token(cfg, cache_dtype=jnp.bfloat16) -> int:
    """KV-cache bytes one token occupies across all attention layers
    (K + V, ``n_kv`` heads each). SSM layers hold recurrent state, not
    per-token cache, so they contribute nothing here."""
    itemsize = jnp.dtype(cache_dtype).itemsize
    total = 0
    for i in range(cfg.n_layers):
        mixer = cfg.layer(i).mixer
        if isinstance(mixer, AttentionSpec):
            total += 2 * mixer.n_kv * mixer.head_dim * itemsize
    return total


@dataclasses.dataclass(frozen=True)
class Placement:
    """One artifact-on-worker sizing decision."""
    artifact: str                   # bundle directory
    memory_bytes: int               # worker budget the plan fits in
    weights_bytes: int              # report.json bytes_after
    density: float                  # params_after / params_before
    kv_token_bytes: int             # KV bytes per cached token
    kv_budget_bytes: int            # budget left for the KV arena
    kv_tokens: int                  # arena capacity, tokens
    serve: ServeConfig              # sized engine construction knobs

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["serve"] = {"max_slots": self.serve.max_slots,
                      "max_seq": self.serve.max_seq,
                      "block_size": self.serve.block_size,
                      "n_blocks": self.serve.n_blocks,
                      "scheduler": self.serve.scheduler}
        return d


def plan_placement(artifact_dir, memory_bytes: int, *,
                   max_seq: int = 256, block_size: Optional[int] = None,
                   max_slots: int = 64, headroom: float = 0.1,
                   cache_dtype=jnp.bfloat16,
                   scheduler: str = "fifo",
                   prefill_chunk: Optional[int] = None) -> Placement:
    """Size slot/block pools for ``artifact_dir`` under ``memory_bytes``.

    ``headroom`` reserves a fraction of the budget for activations and
    runtime overhead. ``max_slots`` is a cap — the planned slot count
    is whatever the leftover KV budget supports, at most this. With a
    ``block_size`` the plan sizes a paged arena (``n_blocks``);
    otherwise slots own contiguous ``max_seq`` regions, which needs
    ``kv_tokens >= max_seq`` per slot and therefore admits fewer.
    """
    root = pathlib.Path(artifact_dir)
    report = json.loads((root / "report.json").read_text())
    cfg = config_from_dict(json.loads((root / "config.json").read_text()))
    weights = int(report["bytes_after"])
    density = (report["params_after"] / report["params_before"]
               if report.get("params_before") else 1.0)
    per_tok = kv_bytes_per_token(cfg, cache_dtype)
    if per_tok == 0:
        raise ValueError("config has no attention layers — paged/"
                         "contiguous KV placement does not apply")
    if block_size is not None:
        # mirror ServeConfig's invariants up front: a bad block size
        # must fail with a clear error here, not a ZeroDivisionError
        # (block_size > max_seq) or a late ServeConfig raise
        if block_size > max_seq or max_seq % block_size:
            raise ValueError(
                f"block_size {block_size} must divide max_seq {max_seq} "
                "(the paged view must match the contiguous pool width "
                "exactly)")
    kv_budget = int(memory_bytes * (1.0 - headroom)) - weights
    tokens = kv_budget // per_tok
    if tokens < max_seq:
        raise ValueError(
            f"memory budget {memory_bytes} cannot hold the weights "
            f"({weights} bytes) plus one {max_seq}-token sequence of KV "
            f"({max_seq * per_tok} bytes at {per_tok} B/token)")
    if block_size is not None:
        # the arena allocates n_blocks + 1 blocks per layer (the +1 is
        # the padding scratch block), so the scratch block's bytes come
        # out of the same budget: a plan sized exactly to memory_bytes
        # must not oversubscribe it
        blocks_per_seq = max_seq // block_size
        n_blocks = tokens // block_size - 1
        if n_blocks < blocks_per_seq:
            raise ValueError(
                f"memory budget {memory_bytes} cannot hold the weights "
                f"({weights} bytes) plus a {max_seq}-token paged arena "
                f"and its scratch block "
                f"({(blocks_per_seq + 1) * block_size * per_tok} bytes)")
        # round the slot cap down to full sequences: a planned slot must
        # always be able to hold max_seq tokens of its own
        slots = max(1, min(max_slots, n_blocks // blocks_per_seq))
        tokens = n_blocks * block_size  # usable capacity (scratch excluded)
        serve = ServeConfig(max_slots=slots, max_seq=max_seq,
                            block_size=block_size, n_blocks=n_blocks,
                            cache_dtype=cache_dtype, scheduler=scheduler,
                            prefill_chunk=prefill_chunk)
    else:
        slots = max(1, min(max_slots, tokens // max_seq))
        tokens = slots * max_seq        # contiguous arena is exact
        serve = ServeConfig(max_slots=slots, max_seq=max_seq,
                            cache_dtype=cache_dtype, scheduler=scheduler)
    return Placement(artifact=str(root), memory_bytes=int(memory_bytes),
                     weights_bytes=weights, density=float(density),
                     kv_token_bytes=per_tok, kv_budget_bytes=kv_budget,
                     kv_tokens=tokens, serve=serve)
