"""Continuous-batching generation engine over a slot-based KV pool.

One jitted decode step runs every tick over *all* slots of a fixed
``(max_slots, max_seq)`` cache pool (per-slot lengths as the vector
``cache_index``), and prefills are admitted between ticks into whatever
slots are free — so requests of different lengths enter and leave the
batch continuously without recompiling the decode step. Prompts are
right-padded to a bucket multiple to bound prefill retraces; padded
positions are masked by the per-slot length and overwritten as the
sequence grows.

With a ``packed`` plan (``sparse.pack_model`` on a Mosaic-pruned model)
the MLP projections run through the Pallas block-sparse kernel inside
the same jitted steps — the pruned fast path in the serving hot loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.specs import AttentionSpec, ModelConfig
from repro.serve.engine import (make_prefill_step, make_serve_step,
                                make_sparse_mlp_apply, sample_token)
from repro.serve.scheduler import Finished, Scheduler


@dataclasses.dataclass
class ServeStats:
    ticks: int
    wall_s: float
    generated_tokens: int
    tokens_per_s: float
    slot_utilization: float     # mean active/max_slots over decode ticks
    prefills: int
    rejected: int


class ContinuousEngine:
    """Slot-pool engine: FIFO admission, per-tick batched decode,
    immediate slot reuse after eviction."""

    def __init__(self, params, cfg: ModelConfig, max_slots: int,
                 max_seq: int, compute_dtype=jnp.bfloat16,
                 cache_dtype=jnp.bfloat16, packed: Optional[dict] = None,
                 interpret: bool = True, prefill_multiple: int = 16,
                 group_experts: Optional[bool] = None):
        if cfg.scan_layers:
            raise ValueError("continuous batching needs an unrolled config "
                             "(cfg.replace(scan_layers=False))")
        if prefill_multiple != 1 and any(
                not isinstance(cfg.layer(i).mixer, AttentionSpec)
                for i in range(cfg.n_layers)):
            # attention masks padded prefill positions via the per-slot
            # length; an SSM integrates them into its recurrent state
            raise ValueError("SSM/hybrid mixers need unpadded prefills: "
                             "pass prefill_multiple=1")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.prefill_multiple = prefill_multiple
        mlp_apply = (make_sparse_mlp_apply(packed, interpret, group_experts)
                     if packed else None)
        self._prefill = jax.jit(
            make_prefill_step(cfg, compute_dtype, mlp_apply))
        decode = make_serve_step(cfg, compute_dtype, mlp_apply)

        # one fused dispatch per tick: decode + sample on device, only
        # the (max_slots,) sampled tokens come back to the host
        def decode_sample(params, pool, tokens, lengths, key, temperature):
            logits, pool = decode(params, pool, tokens, lengths)
            return sample_token(logits, key, temperature, cfg.vocab), pool
        self._decode_sample = jax.jit(decode_sample,
                                      static_argnames=("temperature",))
        self._write = jax.jit(T.write_cache_slot)

    @classmethod
    def from_artifact(cls, artifact, max_slots: int, max_seq: int, *,
                      sparse: bool = True, **kw) -> "ContinuousEngine":
        """Serve a loaded :class:`~repro.core.artifact.PrunedArtifact`:
        the saved block plans are rehydrated into the jitted hot loop —
        no ``pack_model`` at startup. Expert plan stacks keep their
        saved ``group`` flag, so MoE bundles serve through the grouped
        one-launch kernel with zero repacking."""
        packed = artifact.packed if sparse else None
        return cls(artifact.params, artifact.cfg, max_slots=max_slots,
                   max_seq=max_seq, packed=packed or None, **kw)

    # ------------------------------------------------------------ pieces

    def _bucket(self, n: int) -> int:
        m = self.prefill_multiple
        return min(-(-n // m) * m, self.max_seq)

    def _prefill_slot(self, pool, slot, temperature, key):
        """Prefill one request into its slot; returns (pool, first_token)."""
        prompt = np.asarray(slot.request.prompt, np.int32)
        s0 = len(prompt)
        bucket = self._bucket(s0)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :s0] = prompt
        row = T.init_cache(self.cfg, 1, self.max_seq, self.cache_dtype)
        logits, row = self._prefill(self.params, jnp.asarray(padded), row)
        pool = self._write(pool, row, jnp.int32(slot.index))
        tok = sample_token(logits[:, s0 - 1, :], key, temperature,
                           self.cfg.vocab)
        return pool, int(tok[0])

    # -------------------------------------------------------------- run

    def run(self, requests, temperature: float = 0.0, seed: int = 0,
            max_ticks: Optional[int] = None, max_burst: int = 8):
        """Serve ``requests`` to completion.

        Arrivals are seconds on the wall clock starting when ``run`` is
        called (``Request.arrival=0`` = available immediately). Returns
        ``(finished, stats)`` where ``finished`` is uid-sorted
        ``scheduler.Finished`` records.

        Decode runs in bursts of up to ``max_burst`` ticks that chain
        the sampled tokens on-device, so the hot loop stays async and
        only syncs with the host scheduler once per burst. Bursts never
        exceed the smallest remaining per-slot budget, so the only
        waste is an EOS landing mid-burst (those tokens are dropped and
        the slot frees at the burst boundary); the generated sequences
        are identical to tick-by-tick decoding.
        """
        sched = Scheduler(self.max_slots, self.max_seq)
        for r in requests:
            sched.submit(r)
        pool = T.init_cache_pool(self.cfg, self.max_slots, self.max_seq,
                                 self.cache_dtype)
        key = jax.random.PRNGKey(seed)
        t0 = time.perf_counter()
        clock = lambda: time.perf_counter() - t0  # noqa: E731
        ticks = prefills = 0
        util = []
        tokens_in = np.zeros((self.max_slots, 1), np.int32)
        lengths = np.zeros((self.max_slots,), np.int32)

        while sched.has_work():
            if max_ticks is not None and ticks >= max_ticks:
                break
            for slot in sched.admissions(clock()):
                key, sub = jax.random.split(key)
                pool, tok = self._prefill_slot(pool, slot, temperature, sub)
                prefills += 1
                sched.started(slot, tok, clock())
            active = sched.active()
            if not active:
                if sched.queue:     # all arrivals are in the future
                    time.sleep(max(sched.queue[0].arrival - clock(), 0.0))
                continue
            for s in active:
                tokens_in[s.index, 0] = s.last_token
                lengths[s.index] = s.length
            remaining = min(
                min(s.request.max_new_tokens - len(s.generated),
                    self.max_seq - s.length) for s in active)
            burst = max(1, min(max_burst, remaining))
            if max_ticks is not None:
                burst = min(burst, max_ticks - ticks)
            toks_dev = jnp.asarray(tokens_in)
            lens_dev = jnp.asarray(lengths)
            steps = []
            for _ in range(burst):
                key, sub = jax.random.split(key)
                sampled, pool = self._decode_sample(
                    self.params, pool, toks_dev, lens_dev, sub, temperature)
                steps.append(sampled)
                toks_dev = sampled[:, None]
                lens_dev = lens_dev + 1
            host = np.asarray(jnp.stack(steps))    # one sync per burst
            for k in range(burst):
                sched.decoded({s.index: host[k, s.index] for s in active},
                              clock())
                util.append(len(active) / self.max_slots)
                ticks += 1

        wall = clock()
        finished = sorted(sched.finished, key=lambda f: f.request.uid)
        n_tok = sum(len(f.tokens) for f in finished)
        stats = ServeStats(
            ticks=ticks, wall_s=wall, generated_tokens=n_tok,
            tokens_per_s=n_tok / wall if wall > 0 else 0.0,
            slot_utilization=float(np.mean(util)) if util else 0.0,
            prefills=prefills, rejected=len(sched.rejected))
        return finished, stats


def latency_percentiles(finished: list[Finished], p=(50, 99)) -> dict:
    """Request-completion latency (arrival -> finish) percentiles, ms."""
    lats = [(f.finished_at - f.request.arrival) * 1e3 for f in finished]
    if not lats:
        return {f"p{q}": 0.0 for q in p}
    return {f"p{q}": float(np.percentile(lats, q)) for q in p}
