"""Continuous-batching generation engine over a slot-based KV pool.

One jitted decode step runs every tick over *all* slots of the KV pool,
and prefills are admitted between ticks into whatever slots are free —
so requests of different lengths enter and leave the batch continuously
without recompiling the decode step. Prompts are right-padded to a
bucket multiple to bound prefill retraces; padded positions are masked
by the per-slot length and overwritten as the sequence grows.

Two pool backends, selected by ``ServeConfig.block_size``:

- **contiguous** (``block_size=None``): one ``(max_slots, max_seq)``
  region per slot, per-slot lengths as the vector ``cache_index``.
- **paged** (``block_size=N``): fixed-size KV blocks in one shared
  arena (``transformer.init_paged_pool``), per-request block tables
  threaded through the jitted steps, a host-side block allocator with
  refcounts + copy-on-write, prefix sharing keyed on
  ``Request.prefix_id``, and chunked prefill — long prompts enter the
  cache in block-multiple chunks that interleave with decode ticks
  instead of stalling them (``repro.serve.paging``).

With a ``packed`` plan (``sparse.pack_model`` on a Mosaic-pruned model)
the MLP projections run through the Pallas block-sparse kernel inside
the same jitted steps — the pruned fast path in the serving hot loop —
on either backend.

The tick loop is driven through a small *feed* seam: ``run`` wires in a
batch feed (all requests pre-submitted, loop exits when drained) while
``serve_forever`` wires in a live feed pulling from a thread-safe
submission queue and emitting per-token events — the streaming gateway
(``repro.serve.gateway``) runs this in a background thread. Both paths
execute the identical admission/prefill/decode code, so gateway outputs
are token-identical to driving the engine directly. Admission *order*
is a pluggable :mod:`~repro.serve.policies` policy selected by
``ServeConfig.scheduler`` (``fifo`` default, behavior-preserving).
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import time
from collections import Counter
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.specs import AttentionSpec, ModelConfig
from repro.serve import metrics as M
from repro.serve.config import ServeConfig
from repro.serve.engine import (_legacy_serve_config, make_prefill_step,
                                make_serve_step, make_sparse_mlp_apply,
                                request_key, sample_tokens)
from repro.serve.metrics import (MetricsRegistry,  # noqa: F401 (re-export)
                                 latency_percentiles)
from repro.serve.paging import (BlockAllocator, PrefixCache,
                                make_paged_decode_step,
                                make_paged_prefill_step)
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass
class ServeStats:
    ticks: int
    wall_s: float
    generated_tokens: int
    tokens_per_s: float
    slot_utilization: float     # mean active/max_slots over decode ticks
    prefills: int               # completed prompt prefills
    rejected: int
    prefill_chunks: int = 0     # jitted prefill launches (>= prefills
    #                             when chunked prefill splits prompts)
    peak_concurrency: int = 0   # max sequences holding cache at once
    prompt_blocks_shared: int = 0   # paged: prefix-cache block hits
    prefix_hit_rate: float = 0.0    # shared / shareable prompt blocks
    reject_reasons: dict = dataclasses.field(default_factory=dict)
    #                           # {"prompt_too_long": n, ...}

    def to_dict(self) -> dict:
        """JSON-safe dump (the gateway's /metrics stats block)."""
        return dataclasses.asdict(self)


class _BatchFeed:
    """Default feed: every request was pre-submitted by ``run``; the
    loop exits when the scheduler drains, and idles by sleeping until
    the next future arrival (PR 6 semantics, bitwise-preserving)."""

    def pump(self, sched, now: float) -> None:
        pass

    def drained(self) -> bool:
        return True

    def wait(self, sched, clock) -> None:
        if sched.prefilling:
            return                      # chunked prefill still progresses
        arrival = sched.next_arrival()
        if arrival is not None:
            delay = arrival - clock()
            if delay > 0:
                time.sleep(delay)

    def emit_token(self, slot, token: int, now: float) -> None:
        pass

    def emit_finished(self, fin) -> None:
        pass

    def emit_rejected(self, rej) -> None:
        pass


class _QueueFeed(_BatchFeed):
    """Live feed: requests arrive on a thread-safe ``queue.Queue`` and
    events stream out through ``emit`` — the gateway's bridge into the
    tick loop. ``stop`` (a ``threading.Event``) ends the loop once the
    inbox and scheduler are both drained."""

    def __init__(self, inbox: queue_mod.Queue, emit: Callable,
                 stop=None, poll_s: float = 0.002):
        self.inbox = inbox
        self.emit = emit
        self.stop = stop
        self.poll_s = poll_s
        self._staged: list = []

    def pump(self, sched, now: float) -> None:
        while True:
            if self._staged:
                req = self._staged.pop(0)
            else:
                try:
                    req = self.inbox.get_nowait()
                except queue_mod.Empty:
                    break
            # the submission's arrival is its intake time on the
            # engine clock — queue-wait metrics start here
            req.arrival = now
            sched.submit(req)

    def drained(self) -> bool:
        return (self.stop is not None and self.stop.is_set()
                and not self._staged and self.inbox.empty())

    def wait(self, sched, clock) -> None:
        if sched.prefilling:
            return
        try:        # block briefly for the next submission, don't spin
            self._staged.append(self.inbox.get(timeout=self.poll_s))
        except queue_mod.Empty:
            pass

    def emit_token(self, slot, token: int, now: float) -> None:
        self.emit(("token", slot.request.uid,
                   len(slot.generated) - 1, token))

    def emit_finished(self, fin) -> None:
        self.emit(("finished", fin))

    def emit_rejected(self, rej) -> None:
        self.emit(("rejected", rej))


class ContinuousEngine:
    """Slot-pool engine: FIFO admission, per-tick batched decode,
    immediate slot reuse after eviction. Construct with a
    :class:`~repro.serve.config.ServeConfig` (the legacy kwarg surface
    is a deprecation shim)."""

    def __init__(self, params, cfg: ModelConfig, serve=None,
                 max_slots: Optional[int] = None,
                 max_seq: Optional[int] = None, compute_dtype=None,
                 cache_dtype=None, packed: Optional[dict] = None,
                 interpret: Optional[bool] = None,
                 prefill_multiple: Optional[int] = None,
                 group_experts: Optional[bool] = None):
        if isinstance(serve, int):  # legacy positional (max_slots, max_seq)
            if max_slots is not None and max_seq is None:
                max_seq = max_slots
            max_slots, serve = serve, None
        if serve is None:
            serve = _legacy_serve_config(
                "ContinuousEngine", max_slots, max_seq, compute_dtype,
                cache_dtype, interpret, prefill_multiple, group_experts)
        if cfg.scan_layers:
            raise ValueError("continuous batching needs an unrolled config "
                             "(cfg.replace(scan_layers=False))")
        hybrid = any(not isinstance(cfg.layer(i).mixer, AttentionSpec)
                     for i in range(cfg.n_layers))
        if serve.prefill_multiple != 1 and hybrid:
            # attention masks padded prefill positions via the per-slot
            # length; an SSM integrates them into its recurrent state
            raise ValueError("SSM/hybrid mixers need unpadded prefills: "
                             "pass prefill_multiple=1")
        if serve.paged and hybrid:
            raise ValueError("paged KV pools support attention-only "
                             "configs (SSM state is recurrent, not "
                             "positional)")
        self.serve = serve
        self.params = params
        self.cfg = cfg
        self.max_slots = serve.max_slots
        self.max_seq = serve.max_seq
        self.cache_dtype = serve.cache_dtype
        self.prefill_multiple = serve.prefill_multiple
        # per-stage observability: request latencies + tick gauges land
        # here (host-side ring buffers; the gateway's /metrics source)
        self.metrics = MetricsRegistry()
        mlp_apply = (make_sparse_mlp_apply(packed, serve.interpret,
                                           serve.group_experts,
                                           serve.ragged_moe, serve.quant)
                     if packed else None)
        if serve.paged:
            self._prefill = jax.jit(make_paged_prefill_step(
                cfg, serve.compute_dtype, mlp_apply))
            decode = make_paged_decode_step(
                cfg, serve.compute_dtype, mlp_apply,
                paged_kernel=serve.paged_kernel,
                interpret=serve.interpret)
            self._copy_block = jax.jit(T.copy_pool_block)
        else:
            self._prefill = jax.jit(make_prefill_step(
                cfg, serve.compute_dtype, mlp_apply))
            decode = make_serve_step(cfg, serve.compute_dtype, mlp_apply)
            self._write = jax.jit(T.write_cache_slot)

        # one fused dispatch per tick: decode + sample on device, only
        # the (max_slots,) sampled tokens come back to the host.
        # Sampling state is *traced* — per-slot base keys, sample
        # counts, and a per-slot temperature vector — so mixed-
        # temperature batches never retrace the decode step.
        def decode_sample(params, pool, tokens, lengths, bases, counts,
                          temps, *tables):
            logits, pool = decode(params, pool, tokens, lengths, *tables)
            keys = jax.vmap(jax.random.fold_in)(bases, counts)
            return sample_tokens(logits, keys, temps, cfg.vocab), pool
        self._decode_sample = jax.jit(decode_sample)

        def first_sample(logits_row, base, temp):
            key = jax.random.fold_in(base, 0)
            return sample_tokens(logits_row[None], key[None], temp[None],
                                 cfg.vocab)[0]
        self._first_sample = jax.jit(first_sample)

    @classmethod
    def from_artifact(cls, artifact, serve=None,
                      max_seq: Optional[int] = None, *,
                      sparse: bool = True, **kw) -> "ContinuousEngine":
        """Serve a loaded :class:`~repro.core.artifact.PrunedArtifact`:
        the saved block plans are rehydrated into the jitted hot loop —
        no ``pack_model`` at startup. Expert plan stacks keep their
        saved ``group`` flag, so MoE bundles serve through the grouped
        one-launch kernel with zero repacking. ``serve`` is a
        :class:`ServeConfig` (two ints are the deprecated
        ``max_slots, max_seq``)."""
        if isinstance(serve, int):      # legacy (max_slots, max_seq)
            kw["max_slots"], serve = serve, None
        if max_seq is not None:
            kw["max_seq"] = max_seq
        packed = artifact.packed if sparse else None
        return cls(artifact.params, artifact.cfg, serve,
                   packed=packed or None, **kw)

    # ------------------------------------------------------------ pieces

    def _bucket(self, n: int) -> int:
        m = self.prefill_multiple
        return min(-(-n // m) * m, self.max_seq)

    def _request_sampling(self, slot, state, default_temp, run_seed):
        """Bind the request's sampling stream to its slot."""
        req = slot.request
        t = req.temperature if req.temperature is not None else default_temp
        state["bases"][slot.index] = np.asarray(
            request_key(req.seed, req.uid, run_seed))
        state["temps"][slot.index] = t

    def _sample_first(self, logits_row, slot, state):
        """Sample the request's first token from its prefill logits."""
        return int(self._first_sample(
            logits_row, jnp.asarray(state["bases"][slot.index]),
            jnp.asarray(state["temps"][slot.index], jnp.float32)))

    # ----------------------------------------------------------- prefill

    def _prefill_slot(self, pool, slot, state):
        """Contiguous pool: prefill one request into its slot; returns
        (pool, first_token)."""
        prompt = np.asarray(slot.request.prompt, np.int32)
        s0 = len(prompt)
        bucket = self._bucket(s0)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :s0] = prompt
        row = T.init_cache(self.cfg, 1, self.max_seq, self.cache_dtype)
        logits, row = self._prefill(self.params, jnp.asarray(padded), row)
        pool = self._write(pool, row, jnp.int32(slot.index))
        return pool, self._sample_first(logits[0, s0 - 1, :], slot, state)

    def _prefill_chunk(self, pool, slot, tables, state):
        """Paged pool: feed the next chunk of the request's prompt in;
        returns (pool, first_token_or_None)."""
        serve = self.serve
        prompt = slot.request.prompt
        s0 = len(prompt)
        start = slot.prefilled
        chunk = serve.prefill_chunk or (s0 - start)
        end = min(start + chunk, s0)
        n = end - start
        bucket = self._bucket(n)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :n] = prompt[start:end]
        logits, pool = self._prefill(
            self.params, pool, jnp.asarray(padded),
            jnp.asarray(tables[slot.index:slot.index + 1]),
            jnp.asarray([start], jnp.int32), jnp.asarray([n], jnp.int32))
        slot.prefilled = end
        if end < s0:
            return pool, None
        return pool, self._sample_first(logits[0, n - 1, :], slot, state)

    # -------------------------------------------------------------- run

    def _wire(self, sched, feed) -> None:
        """Route scheduler lifecycle events into metrics + the feed."""
        def on_finish(fin):
            M.observe_finished(self.metrics, fin)
            feed.emit_finished(fin)

        def on_reject(rej):
            self.metrics.count(f"requests.rejected.{rej.reason}")
            feed.emit_rejected(rej)

        sched.on_token = feed.emit_token
        sched.on_finish = on_finish
        sched.on_reject = on_reject

    def _sampling_state(self, temperature: float, seed: int) -> dict:
        return {
            "bases": np.zeros((self.max_slots, 2), np.uint32),
            "temps": np.zeros((self.max_slots,), np.float32),
            "default_temp": float(temperature), "run_seed": int(seed),
        }

    def run(self, requests, temperature: float = 0.0, seed: int = 0,
            max_ticks: Optional[int] = None, max_burst: int = 8):
        """Serve ``requests`` to completion.

        Arrivals are seconds on the wall clock starting when ``run`` is
        called (``Request.arrival=0`` = available immediately). Returns
        ``(finished, stats)`` where ``finished`` is uid-sorted
        ``scheduler.Finished`` records.

        ``temperature`` and ``seed`` are *defaults* for requests that
        don't carry their own ``Request.temperature`` / ``Request.seed``
        — sampling knobs are per-request, and a request with its own
        seed samples the same stream regardless of batch composition.

        Decode runs in bursts of up to ``max_burst`` ticks that chain
        the sampled tokens on-device, so the hot loop stays async and
        only syncs with the host scheduler once per burst. Bursts never
        exceed the smallest remaining per-slot budget, so the only
        waste is an EOS landing mid-burst (those tokens are dropped and
        the slot frees at the burst boundary); the generated sequences
        are identical to tick-by-tick decoding.
        """
        sched = Scheduler(self.max_slots, self.max_seq,
                          policy=self.serve.scheduler)
        feed = _BatchFeed()
        self._wire(sched, feed)
        for r in requests:
            sched.submit(r)
        state = self._sampling_state(temperature, seed)
        if self.serve.paged:
            return self._run_paged(sched, state, max_ticks, max_burst, feed)
        return self._run_contiguous(sched, state, max_ticks, max_burst,
                                    feed)

    def serve_forever(self, inbox: queue_mod.Queue, emit: Callable,
                      *, stop, temperature: float = 0.0, seed: int = 0,
                      max_burst: int = 8, poll_s: float = 0.002):
        """Drive the tick loop off a live submission queue (the gateway
        front door runs this in a background thread).

        ``inbox`` is a thread-safe ``queue.Queue`` of
        :class:`~repro.serve.scheduler.Request`; each submission's
        ``arrival`` is stamped with its intake time on the engine
        clock. ``emit(event)`` is called from the engine thread with
        ``("token", uid, index, token)``, ``("finished", Finished)``
        and ``("rejected", Rejection)`` events, in generation order per
        request (tokens surface at burst boundaries, up to
        ``max_burst`` at a time). ``stop`` is a ``threading.Event``:
        once set, the loop finishes the work it has, drains the inbox,
        and returns ``(finished, stats)`` exactly like :meth:`run`.

        Admission order, sampling streams, and every jitted step are
        shared with :meth:`run` — a request submitted here generates
        the same tokens it would generate driving the engine directly.
        """
        sched = Scheduler(self.max_slots, self.max_seq,
                          policy=self.serve.scheduler)
        feed = _QueueFeed(inbox, emit, stop=stop, poll_s=poll_s)
        self._wire(sched, feed)
        state = self._sampling_state(temperature, seed)
        if self.serve.paged:
            return self._run_paged(sched, state, None, max_burst, feed)
        return self._run_contiguous(sched, state, None, max_burst, feed)

    def _decode_burst(self, sched, pool, state, tick_state, max_ticks,
                      max_burst, tables=None):
        """One decode burst over the active slots (both backends);
        returns the updated pool, or None when there is nothing to
        decode."""
        active = sched.active()
        if not active:
            return None
        t_burst = time.perf_counter()
        tokens_in = np.zeros((self.max_slots, 1), np.int32)
        lengths = np.zeros((self.max_slots,), np.int32)
        counts = np.zeros((self.max_slots,), np.int32)
        for s in active:
            tokens_in[s.index, 0] = s.last_token
            lengths[s.index] = s.length
            counts[s.index] = len(s.generated)
        remaining = min(
            min(s.request.max_new_tokens - len(s.generated),
                self.max_seq - s.length) for s in active)
        burst = max(1, min(max_burst, remaining))
        if max_ticks is not None:
            burst = min(burst, max_ticks - tick_state["ticks"])
        extra = ((jnp.asarray(tables),) if tables is not None else ())
        toks_dev = jnp.asarray(tokens_in)
        lens_dev = jnp.asarray(lengths)
        counts_dev = jnp.asarray(counts)
        bases_dev = jnp.asarray(state["bases"])
        temps_dev = jnp.asarray(state["temps"])
        steps = []
        for _ in range(burst):
            sampled, pool = self._decode_sample(
                self.params, pool, toks_dev, lens_dev, bases_dev,
                counts_dev, temps_dev, *extra)
            steps.append(sampled)
            toks_dev = sampled[:, None]
            lens_dev = lens_dev + 1
            counts_dev = counts_dev + 1
        host = np.asarray(jnp.stack(steps))    # one sync per burst
        for k in range(burst):
            sched.decoded({s.index: host[k, s.index] for s in active},
                          tick_state["clock"]())
            tick_state["util"].append(len(active) / self.max_slots)
            tick_state["ticks"] += 1
        burst_s = time.perf_counter() - t_burst
        m = self.metrics
        m.observe("tick.active_slots", len(active))
        m.observe("tick.prefill_backlog",
                  len(sched.prefilling) + len(sched.queue))
        if burst_s > 0:
            m.gauge("tick.tokens_per_s", burst * len(active) / burst_s)
        m.count("decode.ticks", burst)
        m.count("decode.tokens", burst * len(active))
        return pool

    def _stats(self, sched, tick_state, wall, prefills, chunks):
        finished = sorted(sched.finished, key=lambda f: f.request.uid)
        n_tok = sum(len(f.tokens) for f in finished)
        shared = sum(f.prompt_blocks_shared for f in finished)
        shareable = 0
        if self.serve.paged:
            bs = self.serve.block_size
            shareable = sum((len(f.request.prompt) - 1) // bs
                            for f in finished
                            if f.request.prefix_id is not None)
        util = tick_state["util"]
        return finished, ServeStats(
            ticks=tick_state["ticks"], wall_s=wall,
            generated_tokens=n_tok,
            tokens_per_s=n_tok / wall if wall > 0 else 0.0,
            slot_utilization=float(np.mean(util)) if util else 0.0,
            prefills=prefills, rejected=len(sched.rejected),
            prefill_chunks=chunks,
            peak_concurrency=tick_state["peak"],
            prompt_blocks_shared=shared,
            prefix_hit_rate=shared / shareable if shareable else 0.0,
            reject_reasons=dict(Counter(r.reason
                                        for r in sched.rejected)))

    # ------------------------------------------------- contiguous backend

    def _run_contiguous(self, sched, state, max_ticks, max_burst, feed):
        pool = T.init_cache_pool(self.cfg, self.max_slots, self.max_seq,
                                 self.cache_dtype)
        t0 = time.perf_counter()
        clock = lambda: time.perf_counter() - t0  # noqa: E731
        tick_state = {"ticks": 0, "util": [], "peak": 0, "clock": clock}
        prefills = 0

        while True:
            feed.pump(sched, clock())
            if not sched.has_work():
                if feed.drained():
                    break
                feed.wait(sched, clock)
                continue
            if max_ticks is not None and tick_state["ticks"] >= max_ticks:
                break
            for slot in sched.admissions(clock()):
                self._request_sampling(slot, state, state["default_temp"],
                                       state["run_seed"])
                pool, tok = self._prefill_slot(pool, slot, state)
                prefills += 1
                sched.started(slot, tok, clock())
            tick_state["peak"] = max(tick_state["peak"],
                                     sched.concurrency())
            new_pool = self._decode_burst(sched, pool, state, tick_state,
                                          max_ticks, max_burst)
            if new_pool is None:
                feed.wait(sched, clock)     # future arrivals / live inbox
                continue
            pool = new_pool

        return self._stats(sched, tick_state, clock(), prefills, prefills)

    # ------------------------------------------------------ paged backend

    def _blocks_for(self, req, prefix: PrefixCache) -> int:
        """Blocks a request must *own*: enough for every KV position it
        can write (prompt + budget, capped at max_seq), minus blocks a
        prefix-cache hit would map in, plus one copy-on-write reserve
        when entering on shared blocks. Reserved in full at admission,
        so neither decode nor COW can run out of blocks mid-request."""
        bs = self.serve.block_size
        cap = min(len(req.prompt) + req.max_new_tokens, self.max_seq)
        shared = len(prefix.match(req.prefix_id, req.prompt))
        own = -(-cap // bs) - shared
        return own + (1 if shared else 0)

    def _run_paged(self, sched, state, max_ticks, max_burst, feed):
        serve = self.serve
        bs = serve.block_size
        alloc = BlockAllocator(serve.arena_blocks, bs)
        prefix = PrefixCache(alloc)
        pool = T.init_paged_pool(self.cfg, serve.arena_blocks, bs,
                                 self.cache_dtype)
        tables = np.full((self.max_slots, serve.blocks_per_seq),
                         alloc.scratch, np.int32)
        slot_blocks: dict[int, list] = {}
        # slot index -> block pre-claimed at admission for copy-on-write
        # (only slots that entered on shared prefix blocks have one)
        slot_reserve: dict[int, int] = {}
        t0 = time.perf_counter()
        clock = lambda: time.perf_counter() - t0  # noqa: E731
        tick_state = {"ticks": 0, "util": [], "peak": 0, "clock": clock}
        prefills = chunks = 0

        # blocks are *reserved* inside the admission gate — several
        # requests can be admitted in one scheduler call, so checking
        # n_free without claiming would over-admit against the same
        # free blocks
        pending: dict[int, tuple] = {}      # uid -> (shared, owned)

        def can_admit(req):
            if req.uid in pending:
                return True
            shared = prefix.match(req.prefix_id, req.prompt)
            need = self._blocks_for(req, prefix)
            if need > alloc.n_free:
                return False
            if shared:
                alloc.retain(shared)
            pending[req.uid] = (list(shared), alloc.alloc(need))
            return True

        def release_if_finished(slot):
            if (slot.index not in sched.slots
                    and slot.index not in sched.prefilling):
                blocks = slot_blocks.pop(slot.index, None)
                if blocks:
                    alloc.release(blocks)
                reserve = slot_reserve.pop(slot.index, None)
                if reserve is not None:
                    alloc.release([reserve])    # COW never fired
                tables[slot.index, :] = alloc.scratch

        while True:
            feed.pump(sched, clock())
            if not sched.has_work():
                if feed.drained():
                    break
                feed.wait(sched, clock)
                continue
            if max_ticks is not None and tick_state["ticks"] >= max_ticks:
                break

            # ---- admissions: map shared prefix blocks + claim the rest
            admitted = sched.admissions(clock(), can_admit)
            if (not admitted and not sched.slots and not sched.prefilling
                    and sched.head(clock()) is not None):
                # head blocked with the pool idle: cached prefixes are
                # the only block holders — drop them and retry; a head
                # that still doesn't fit can never run
                if len(prefix):
                    prefix.drop_all()
                    admitted = sched.admissions(clock(), can_admit)
                head = sched.head(clock())
                if (not admitted and head is not None
                        and not can_admit(head)):
                    sched.reject(sched.pop_head(), "insufficient_blocks",
                                 clock())
                    continue
            for slot in admitted:
                req = slot.request
                shared, owned = pending.pop(req.uid)
                if shared:
                    # the last claimed block is the COW reserve: held
                    # outside the table until a shared-block write needs
                    # a private copy (or released at finish, unused)
                    slot_reserve[slot.index] = owned.pop()
                row = shared + owned
                tables[slot.index, :] = alloc.scratch
                tables[slot.index, :len(row)] = row
                slot_blocks[slot.index] = row
                slot.shared_blocks = len(shared)
                slot.prefilled = len(shared) * bs
                self._request_sampling(slot, state,
                                       state["default_temp"],
                                       state["run_seed"])
            for shared, owned in pending.values():  # reserved, not admitted
                if shared:
                    alloc.release(shared)
                alloc.release(owned)
            pending.clear()
            tick_state["peak"] = max(tick_state["peak"],
                                     sched.concurrency())

            # ---- chunked prefill: one chunk per prefilling slot per
            # tick, interleaved with the decode burst below; the policy
            # may cap chunk launches per tick while slots are decoding
            # (the slo policy's prefill/decode interleave budget) so
            # long-prompt admissions can't starve decode ticks
            prefill_slots = list(sched.prefilling.values())
            # sched.slots holds *started* (decoding) slots only —
            # prefilling slots live in the disjoint sched.prefilling
            # dict — so this is the decoding count the policy contract
            # wants: unlimited chunks while nothing is decoding
            n_decoding = len(sched.slots)
            budget = sched.policy.prefill_budget(n_decoding)
            if budget is not None:
                prefill_slots = prefill_slots[:budget]
            for slot in prefill_slots:
                pool, tok = self._prefill_chunk(pool, slot, tables, state)
                chunks += 1
                if tok is not None:
                    prefills += 1
                    sched.started(slot, tok, clock())
                    prefix.register(slot.request.prefix_id,
                                    slot.request.prompt,
                                    tables[slot.index])
                    release_if_finished(slot)

            # ---- copy-on-write guard: a decode write may never land in
            # a block another sequence can still read. The private copy
            # comes out of the slot's admission-time reserve, never a
            # fresh alloc — a full arena here must not raise OutOfBlocks
            active = sched.active()
            for s in active:
                j = s.length // bs
                old = int(tables[s.index][j])
                reserve = slot_reserve.get(s.index)
                pool = alloc.ensure_writable(
                    tables[s.index], j, pool, reserve=reserve)
                new = int(tables[s.index][j])
                if new != old:
                    # the ownership list must track the swap: the shared
                    # block's ref was dropped by ensure_writable; the
                    # private copy is released at finish instead
                    row = slot_blocks[s.index]
                    row[row.index(old)] = new
                    slot_reserve.pop(s.index, None)
                elif reserve is not None:
                    # first guarded decode tick and no copy was needed:
                    # every shared block sits strictly below the write
                    # frontier and the frontier block is now exclusively
                    # ours, so COW can never fire for this slot again —
                    # return the reserve instead of taxing the arena for
                    # the slot's whole lifetime
                    alloc.release([reserve])
                    slot_reserve.pop(s.index, None)

            # the decode step runs over *every* slot row; slots still
            # mid-chunked-prefill must not have their real blocks
            # stomped by the inactive-row write at position 0, so their
            # table rows are masked to the scratch block for the burst
            decode_tables = tables
            if sched.prefilling:
                decode_tables = tables.copy()
                decode_tables[list(sched.prefilling)] = alloc.scratch
            self.metrics.gauge("tick.free_blocks", alloc.n_free)
            new_pool = self._decode_burst(sched, pool, state, tick_state,
                                          max_ticks, max_burst,
                                          tables=decode_tables)
            if new_pool is None:
                feed.wait(sched, clock)     # future arrivals / live inbox
                continue
            pool = new_pool
            for s in active:
                release_if_finished(s)

        prefix.drop_all()
        return self._stats(sched, tick_state, clock(), prefills, chunks)
