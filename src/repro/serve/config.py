"""ServeConfig: the one construction surface both serving engines share.

Before this module, ``Engine`` and ``ContinuousEngine`` had divergent
kwarg constructors (``max_seq`` here, ``max_slots``/``prefill_multiple``
there) and two different ``from_artifact`` shapes. Every engine now
takes a single frozen :class:`ServeConfig` and exposes the same
``from_artifact(artifact, serve_cfg, *, sparse=True)`` classmethod; the
old kwarg constructors survive as thin deprecation shims that assemble
a ``ServeConfig`` internally.

``block_size`` selects the KV pool backend: ``None`` keeps the
contiguous per-slot pool, an int switches the continuous engine to the
paged pool (fixed-size KV blocks + per-request block tables, prefix
sharing, chunked prefill — see ``repro.serve.paging``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine-construction knobs shared by ``Engine`` and
    ``ContinuousEngine`` (fields irrelevant to an engine are ignored by
    it — the static engine has no slots or prefill buckets)."""

    max_slots: int = 4              # concurrent sequences (continuous)
    max_seq: int = 256              # per-sequence KV capacity, tokens
    block_size: Optional[int] = None  # None = contiguous pool; int = paged
    n_blocks: Optional[int] = None  # paged arena size; None = the byte
    #                                 budget of the contiguous pool
    #                                 (max_slots * max_seq / block_size)
    compute_dtype: Any = jnp.bfloat16
    cache_dtype: Any = jnp.bfloat16
    prefill_multiple: int = 16      # prompt right-pad bucket, bounds
    #                                 prefill retraces
    prefill_chunk: Optional[int] = None  # paged: split prompts into
    #                                 chunks of this many tokens that
    #                                 interleave with decode ticks
    #                                 (block_size multiple); None = one
    #                                 prefill per prompt
    group_experts: Optional[bool] = None  # MoE: grouped one-launch
    #                                 kernel (None follows plan flags)
    ragged_moe: Optional[bool] = None  # MoE: ragged (routed-tokens-only)
    #                                 dispatch at decode batch sizes
    #                                 (None follows plan flags)
    quant: Optional[str] = None     # projection weight storage: "int8"
    #                                 streams the plans' kept-tile int8
    #                                 storage (requires a quantized
    #                                 pack), "none" forces the
    #                                 dequantized reference path, None
    #                                 follows plan flags
    paged_kernel: bool = False      # paged decode: fused Pallas
    #                                 paged-attention kernel instead of
    #                                 the gather path (needs block_size)
    interpret: bool = True          # Pallas interpret mode (CPU)
    scheduler: str = "fifo"         # admission policy name from
    #                                 repro.serve.policies.SCHEDULERS:
    #                                 fifo | priority | slo

    def __post_init__(self):
        from repro.core.recipe import QUANT_MODES
        from repro.serve.policies import SCHEDULERS
        if self.scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r}; "
                             f"registered: {SCHEDULERS.names()}")
        if self.quant is not None and self.quant not in QUANT_MODES:
            raise ValueError(f"unknown quant {self.quant!r}; "
                             f"choices: {QUANT_MODES} or None")
        if self.block_size is not None:
            if self.max_seq % self.block_size:
                raise ValueError(
                    f"max_seq {self.max_seq} must be a multiple of "
                    f"block_size {self.block_size} (the paged view must "
                    "match the contiguous pool width exactly)")
            if (self.prefill_chunk is not None
                    and self.prefill_chunk % self.block_size):
                raise ValueError(
                    f"prefill_chunk {self.prefill_chunk} must be a "
                    f"multiple of block_size {self.block_size}")
        elif self.prefill_chunk is not None:
            raise ValueError("prefill_chunk needs a paged pool "
                             "(set block_size)")
        if self.paged_kernel and self.block_size is None:
            raise ValueError("paged_kernel needs a paged pool "
                             "(set block_size)")

    # ------------------------------------------------------------ paged

    @property
    def paged(self) -> bool:
        return self.block_size is not None

    @property
    def blocks_per_seq(self) -> int:
        """Block-table width: logical blocks covering ``max_seq``."""
        return self.max_seq // self.block_size

    @property
    def arena_blocks(self) -> int:
        """Usable arena blocks (the scratch block is extra). Defaults to
        the contiguous pool's exact token capacity, so paged-vs-
        contiguous comparisons are at the same cache-arena byte
        budget."""
        if self.n_blocks is not None:
            return self.n_blocks
        return -(-self.max_slots * self.max_seq // self.block_size)
