"""Pluggable admission-order policies for the continuous engine.

The :class:`~repro.serve.scheduler.Scheduler` owns slots and lifecycle;
*which waiting request is admitted next* is a policy plugged in through
the same :class:`~repro.core.registry.Registry` mechanism the pruning
pipeline uses for selectors/categories/stages. Registered policies:

- ``fifo`` (default) — strict arrival order, behavior-preserving with
  the pre-policy scheduler: a request that cannot be admitted (no slot,
  or the engine's resource gate says no) holds the queue head; nothing
  is reordered.
- ``priority`` — highest ``Request.priority`` first, with *aging*: each
  time a later-submitted request is popped past a waiting one, the
  waiting request's effective priority rises by ``aging`` — sustained
  high-priority load can therefore delay but never starve a
  low-priority request. Aging is bypass-counted (not wall-clock), so
  admission order is deterministic for a given workload.
- ``slo`` — earliest-deadline-first over ``Request.deadline_ms``
  (absolute deadline = arrival + deadline_ms; no deadline = +inf, FIFO
  among themselves), plus a prefill/decode interleave budget: at most
  ``prefill_budget`` chunked-prefill launches per tick while any slot
  is decoding, so long-prompt admissions cannot starve decode ticks.

All policies keep the scheduler's hold-the-head backpressure semantics:
``can_admit(head) == False`` stalls admission (in the policy's order)
rather than skipping to a smaller request — no resource-driven
reordering, so completion order stays a pure function of the policy.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Optional

from repro.core.registry import Registry

SCHEDULERS = Registry("scheduler")
register_scheduler = SCHEDULERS.register


class SchedulerPolicy:
    """Admission-queue interface.

    The scheduler calls ``head(now)`` for the next candidate (or None
    when nothing has arrived), then ``pop()`` to commit the admission —
    ``pop`` always removes the request the last ``head`` returned.
    ``next_arrival()`` lets the engine sleep until work exists;
    ``prefill_budget(n_decoding)`` caps chunked-prefill launches per
    tick (None = unlimited).
    """

    def push(self, req) -> None:
        raise NotImplementedError

    def head(self, now: float):
        raise NotImplementedError

    def pop(self):
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def __bool__(self) -> bool:
        return len(self) > 0

    def next_arrival(self) -> Optional[float]:
        raise NotImplementedError

    def prefill_budget(self, n_decoding: int) -> Optional[int]:
        return None


@register_scheduler("fifo")
class FifoPolicy(SchedulerPolicy):
    """Strict arrival order (PR 6 semantics, bitwise-preserving)."""

    def __init__(self):
        self._q: deque = deque()

    def push(self, req) -> None:
        self._q.append(req)

    def head(self, now: float):
        if self._q and self._q[0].arrival <= now:
            return self._q[0]
        return None

    def pop(self):
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def next_arrival(self) -> Optional[float]:
        return self._q[0].arrival if self._q else None


class _Entry:
    __slots__ = ("req", "seq", "age")

    def __init__(self, req, seq):
        self.req = req
        self.seq = seq
        self.age = 0


class _OrderedPolicy(SchedulerPolicy):
    """Shared machinery: linear scan over arrived entries by a key."""

    def __init__(self):
        self._waiting: list[_Entry] = []
        self._seq = 0
        self._head: Optional[_Entry] = None

    def push(self, req) -> None:
        self._waiting.append(_Entry(req, self._seq))
        self._seq += 1

    def _key(self, entry: _Entry):
        raise NotImplementedError

    def head(self, now: float):
        arrived = [e for e in self._waiting if e.req.arrival <= now]
        if not arrived:
            self._head = None
            return None
        self._head = min(arrived, key=self._key)
        return self._head.req

    def pop(self):
        entry = self._head
        assert entry is not None, "pop() without a preceding head() hit"
        self._waiting.remove(entry)
        self._head = None
        self._on_pop(entry)
        return entry.req

    def _on_pop(self, popped: _Entry) -> None:
        pass

    def __len__(self) -> int:
        return len(self._waiting)

    def next_arrival(self) -> Optional[float]:
        if not self._waiting:
            return None
        return min(e.req.arrival for e in self._waiting)


@register_scheduler("priority")
class PriorityPolicy(_OrderedPolicy):
    """Highest ``Request.priority`` first; bypass-counted aging."""

    def __init__(self, aging: float = 1.0):
        super().__init__()
        self.aging = aging

    def _effective(self, e: _Entry) -> float:
        return (e.req.priority or 0) + self.aging * e.age

    def _key(self, e: _Entry):
        # min() over (-effective priority, submission order)
        return (-self._effective(e), e.seq)

    def _on_pop(self, popped: _Entry) -> None:
        # every earlier-submitted request just bypassed ages one step
        for e in self._waiting:
            if e.seq < popped.seq:
                e.age += 1


@register_scheduler("slo")
class SLOPolicy(_OrderedPolicy):
    """Earliest absolute deadline first + prefill interleave budget."""

    def __init__(self, prefill_budget: int = 1):
        super().__init__()
        self._budget = prefill_budget

    @staticmethod
    def deadline_at(req) -> float:
        if req.deadline_ms is None:
            return math.inf
        return req.arrival + req.deadline_ms / 1e3

    def _key(self, e: _Entry):
        return (self.deadline_at(e.req), e.seq)

    def prefill_budget(self, n_decoding: int) -> Optional[int]:
        # unlimited while nothing is decoding (no one to starve)
        return self._budget if n_decoding else None


def make_policy(name: str) -> SchedulerPolicy:
    """Fresh policy instance (policies hold per-run queue state)."""
    return SCHEDULERS.get(name)()
