"""Jit-ready wrappers: block-map construction from unstructured-pruning
masks + the dispatch into the Pallas kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import counters
from repro.kernels.block_sparse.kernel import (block_sparse_matmul,
                                               quant_block_sparse_matmul)


def block_mask_from_weight_mask(mask, block_k: int, block_n: int):
    """Elementwise keep-mask (K, N) -> block-level nonzero map (K/bk, N/bn)."""
    K, N = mask.shape
    assert K % block_k == 0 and N % block_n == 0
    m = np.asarray(mask).reshape(K // block_k, block_k, N // block_n, block_n)
    return m.any(axis=(1, 3))


def plan_blocks(block_mask) -> tuple:
    """Block map -> (counts (nN,), indices (nN, max_nnz)) for the kernel."""
    bm = np.asarray(block_mask)
    nK, nN = bm.shape
    counts = bm.sum(axis=0).astype(np.int32)
    max_nnz = max(int(counts.max()), 1)
    indices = np.zeros((nN, max_nnz), np.int32)
    for n in range(nN):
        nz = np.nonzero(bm[:, n])[0]
        if len(nz) == 0:
            nz = np.array([0])
        pad = np.full(max_nnz - min(len(nz), max_nnz), nz[-1])
        indices[n] = np.concatenate([nz[:max_nnz], pad])
    return jnp.asarray(counts), jnp.asarray(indices)


def plan_slots(counts, max_nnz: int) -> tuple:
    """Compact-tile slot map for the quantized kernels.

    Kept tiles are stored in plan order — column n's tiles occupy
    consecutive storage rows — and ``slots[n, s]`` names the storage row
    of column ``n``'s step-``s`` tile. Steps past ``counts[n]`` clamp to
    the column's last kept tile (the revisit's DMA is elided), empty
    columns to row 0. Returns ``(slots (nN, max_nnz) int32, total)``
    where ``total`` is the kept-tile count (storage always holds
    ``max(total, 1)`` tiles)."""
    c = np.asarray(counts)
    off = np.concatenate([[0], np.cumsum(c)[:-1]]).astype(np.int64)
    total = int(c.sum())
    steps = np.minimum(np.arange(max_nnz)[None, :],
                       np.maximum(c - 1, 0)[:, None])
    slots = off[:, None] + steps
    return np.clip(slots, 0, max(total, 1) - 1).astype(np.int32), total


def gather_kept_tiles(w2, counts, indices, block_k: int,
                      block_n: int) -> np.ndarray:
    """The kept (block_k, block_n) tiles of a planned weight, stacked in
    plan order — the storage the quantized kernels stream instead of the
    dense weight. Returns (max(total, 1), block_k, block_n) float32 (a
    single zero tile when the plan keeps nothing)."""
    w2 = np.asarray(w2, np.float32)
    c = np.asarray(counts)
    idx = np.asarray(indices)
    tiles = []
    for n in range(c.shape[0]):
        for s in range(int(c[n])):
            k = int(idx[n, s])
            tiles.append(w2[k * block_k:(k + 1) * block_k,
                            n * block_n:(n + 1) * block_n])
    if not tiles:
        tiles = [np.zeros((block_k, block_n), np.float32)]
    return np.stack(tiles)


def sparse_density(block_mask) -> float:
    bm = np.asarray(block_mask)
    return float(bm.mean())


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _blocksparse_matmul_jit(x, w, counts, indices, block_m, block_k,
                            block_n, interpret):
    return block_sparse_matmul(x, w, counts, indices, block_m=block_m,
                               block_k=block_k, block_n=block_n,
                               interpret=interpret)


def blocksparse_matmul(x, w, counts, indices, block_m=128, block_k=128,
                       block_n=128, interpret=False):
    """Public op: y = x @ w visiting nonzero weight blocks only."""
    counters.record("block_sparse")
    return _blocksparse_matmul_jit(x, w, counts, indices, block_m, block_k,
                                   block_n, interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _quant_blocksparse_matmul_jit(x, tiles, counts, indices, slots, scales,
                                  block_m, block_k, block_n, interpret):
    return quant_block_sparse_matmul(x, tiles, counts, indices, slots,
                                     scales, block_m=block_m,
                                     block_k=block_k, block_n=block_n,
                                     interpret=interpret)


def quant_blocksparse_matmul(x, tiles, counts, indices, slots, scales,
                             block_m=128, block_k=128, block_n=128,
                             interpret=False):
    """Public op: y = x @ w with kept tiles stored int8 + pow2 scales."""
    counters.record("block_sparse_quant")
    return _quant_blocksparse_matmul_jit(x, tiles, counts, indices, slots,
                                         scales, block_m, block_k, block_n,
                                         interpret)
