"""Jit-ready wrappers: block-map construction from unstructured-pruning
masks + the dispatch into the Pallas kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import counters
from repro.kernels.block_sparse.kernel import block_sparse_matmul


def block_mask_from_weight_mask(mask, block_k: int, block_n: int):
    """Elementwise keep-mask (K, N) -> block-level nonzero map (K/bk, N/bn)."""
    K, N = mask.shape
    assert K % block_k == 0 and N % block_n == 0
    m = np.asarray(mask).reshape(K // block_k, block_k, N // block_n, block_n)
    return m.any(axis=(1, 3))


def plan_blocks(block_mask) -> tuple:
    """Block map -> (counts (nN,), indices (nN, max_nnz)) for the kernel."""
    bm = np.asarray(block_mask)
    nK, nN = bm.shape
    counts = bm.sum(axis=0).astype(np.int32)
    max_nnz = max(int(counts.max()), 1)
    indices = np.zeros((nN, max_nnz), np.int32)
    for n in range(nN):
        nz = np.nonzero(bm[:, n])[0]
        if len(nz) == 0:
            nz = np.array([0])
        pad = np.full(max_nnz - min(len(nz), max_nnz), nz[-1])
        indices[n] = np.concatenate([nz[:max_nnz], pad])
    return jnp.asarray(counts), jnp.asarray(indices)


def sparse_density(block_mask) -> float:
    bm = np.asarray(block_mask)
    return float(bm.mean())


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _blocksparse_matmul_jit(x, w, counts, indices, block_m, block_k,
                            block_n, interpret):
    return block_sparse_matmul(x, w, counts, indices, block_m=block_m,
                               block_k=block_k, block_n=block_n,
                               interpret=interpret)


def blocksparse_matmul(x, w, counts, indices, block_m=128, block_k=128,
                       block_n=128, interpret=False):
    """Public op: y = x @ w visiting nonzero weight blocks only."""
    counters.record("block_sparse")
    return _blocksparse_matmul_jit(x, w, counts, indices, block_m, block_k,
                                   block_n, interpret)
