"""Pure-jnp oracle for the block-sparse GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_sparse_matmul_ref(x: jax.Array, w: jax.Array,
                            block_mask: jax.Array, block_k: int,
                            block_n: int) -> jax.Array:
    """y = x @ (w with pruned blocks zeroed). block_mask: (K/bk, N/bn)."""
    K, N = w.shape
    mask = jnp.repeat(jnp.repeat(block_mask, block_k, axis=0), block_n, axis=1)
    return x @ jnp.where(mask, w, jnp.zeros_like(w))
