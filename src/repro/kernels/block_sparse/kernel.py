"""Block-sparse GEMM Pallas TPU kernel — the TPU-native realisation of
composite-pruned projection matmuls (DESIGN.md §3.1).

Unstructured pruning at high POD targets leaves many all-zero 128x128
weight tiles. The kernel walks, per output block-column, a scalar-
prefetched list of the *nonzero* K-block indices only (MegaBlocks /
SplashAttention pattern): zero blocks cost neither HBM->VMEM traffic nor
MXU cycles. Grid = (M-blocks, N-blocks, max_nnz); padded steps are
masked out with @pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(count_ref, idx_ref, x_ref, w_ref, o_ref, acc_ref, *,
            max_nnz: int):
    n = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < count_ref[n])
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_sparse_matmul(x: jax.Array, w: jax.Array, counts: jax.Array,
                        indices: jax.Array, *, block_m: int = 128,
                        block_k: int = 128, block_n: int = 128,
                        interpret: bool = False) -> jax.Array:
    """y = x @ w, visiting only nonzero (K-block, N-block) weight tiles.

    x: (M, K); w: (K, N) (zeros in pruned blocks);
    counts: (N/bn,) int32 — nonzero K-blocks per output block-column;
    indices: (N/bn, max_nnz) int32 — their K-block ids (padded by repeating
    the last valid id so prefetch stays in-bounds).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    max_nnz = indices.shape[1]

    grid = (M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda m, n, s, cnt, idx: (m, idx[n, s])),
                pl.BlockSpec((block_k, block_n),
                             lambda m, n, s, cnt, idx: (idx[n, s], n)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda m, n, s, cnt, idx: (m, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, x, w)
