"""Block-sparse GEMM Pallas TPU kernel — the TPU-native realisation of
composite-pruned projection matmuls (DESIGN.md §3.1).

Unstructured pruning at high POD targets leaves many all-zero 128x128
weight tiles. The kernel walks, per output block-column, a scalar-
prefetched list of the *nonzero* K-block indices only (MegaBlocks /
SplashAttention pattern): zero blocks cost neither HBM->VMEM traffic nor
MXU cycles. Grid = (M-blocks, N-blocks, max_nnz); padded steps are
masked out with @pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(count_ref, idx_ref, x_ref, w_ref, o_ref, acc_ref, *,
            max_nnz: int):
    n = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < count_ref[n])
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _quant_kernel(count_ref, idx_ref, slot_ref, scale_ref, x_ref, w_ref,
                  o_ref, acc_ref, *, max_nnz: int):
    n = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < count_ref[n])
    def _accum():
        # int8 magnitudes are exact in the compute dtype and the per-tile
        # scale is a power of two, so scaling the accumulated tile
        # product is bitwise-equal to pre-scaling the weight tile
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0].astype(x_ref.dtype),
                                preferred_element_type=jnp.float32
                                ) * scale_ref[n, s]

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_block_sparse_matmul(x: jax.Array, tiles: jax.Array,
                              counts: jax.Array, indices: jax.Array,
                              slots: jax.Array, scales: jax.Array, *,
                              block_m: int = 128, block_k: int = 128,
                              block_n: int = 128,
                              interpret: bool = False) -> jax.Array:
    """y = x @ w with the kept weight tiles stored as compacted int8.

    Same tile walk as :func:`block_sparse_matmul`, but instead of the
    dense (K, N) weight the kernel streams ``tiles`` — the plan's kept
    (block_k, block_n) tiles stacked in plan order as int8 — locating
    column ``n``'s step-``s`` tile via the scalar-prefetched
    ``slots (N/bn, max_nnz)`` map. ``scales (N/bn, max_nnz)`` holds the
    matching per-tile power-of-two dequant factors, applied once per
    tile to the accumulated product. Dead steps clamp their slot to the
    column's last kept tile so the revisit's DMA is elided.
    """
    M, K = x.shape
    assert tiles.shape[1:] == (block_k, block_n)
    N = counts.shape[0] * block_n
    assert M % block_m == 0 and K % block_k == 0
    max_nnz = indices.shape[1]

    grid = (M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_quant_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda m, n, s, cnt, idx, slt, scl:
                             (m, idx[n, s])),
                pl.BlockSpec((1, block_k, block_n),
                             lambda m, n, s, cnt, idx, slt, scl:
                             (slt[n, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda m, n, s, cnt, idx, slt, scl:
                                   (m, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, slots, scales, x, tiles)


def block_sparse_matmul(x: jax.Array, w: jax.Array, counts: jax.Array,
                        indices: jax.Array, *, block_m: int = 128,
                        block_k: int = 128, block_n: int = 128,
                        interpret: bool = False) -> jax.Array:
    """y = x @ w, visiting only nonzero (K-block, N-block) weight tiles.

    x: (M, K); w: (K, N) (zeros in pruned blocks);
    counts: (N/bn,) int32 — nonzero K-blocks per output block-column;
    indices: (N/bn, max_nnz) int32 — their K-block ids (padded by repeating
    the last valid id so prefetch stays in-bounds).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    max_nnz = indices.shape[1]

    grid = (M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda m, n, s, cnt, idx: (m, idx[n, s])),
                pl.BlockSpec((block_k, block_n),
                             lambda m, n, s, cnt, idx: (idx[n, s], n)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda m, n, s, cnt, idx: (m, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, x, w)
