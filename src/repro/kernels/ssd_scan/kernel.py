"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid = (B·H, n_chunks); the (P, N) recurrent state lives in VMEM scratch
and persists across the sequentially-executed chunk dimension (TPU grids
iterate the last axis innermost), so the inter-chunk recurrence costs no
HBM round-trips. Intra-chunk work is two MXU matmuls over (Q, N)/(Q, P)
tiles — the attention-duality form of SSD.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xt_ref, da_ref, b_ref, c_ref, o_ref, state_ref, *, chunk: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xt = xt_ref[0, 0].astype(jnp.float32)             # (Q, P)
    da = da_ref[0, 0, :, 0].astype(jnp.float32)       # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)              # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)              # (Q, N)

    Lc = jnp.cumsum(da)                               # (Q,)
    seg = jnp.exp(Lc[:, None] - Lc[None, :])
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(idx >= jdx, seg, 0.0)

    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)   # (Q, Q)
    y_intra = jnp.dot(CB * seg, xt, preferred_element_type=jnp.float32)

    state = state_ref[...]                            # (N, P)
    y_inter = jnp.dot(Cm * jnp.exp(Lc)[:, None], state,
                      preferred_element_type=jnp.float32)        # (Q, P)

    decay_end = jnp.exp(Lc[-1] - Lc)                  # (Q,)
    chunk_state = jnp.dot((Bm * decay_end[:, None]).T, xt,
                          preferred_element_type=jnp.float32)    # (N, P)
    state_ref[...] = state * jnp.exp(Lc[-1]) + chunk_state

    o_ref[0, 0] = (y_intra + y_inter).astype(o_ref.dtype)


def ssd_scan(xt: jax.Array, da: jax.Array, Bm: jax.Array, Cm: jax.Array,
             *, chunk: int = 256, interpret: bool = False) -> jax.Array:
    """xt: (BH, L, P) dt-scaled inputs; da: (BH, L) log-decays;
    Bm/Cm: (BH, L, N) per-head-broadcast projections. Returns (BH, L, P).
    """
    BH, L, P = xt.shape
    N = Bm.shape[-1]
    assert L % chunk == 0
    nc = L // chunk
    xt4 = xt.reshape(BH, nc, chunk, P)
    da4 = da.reshape(BH, nc, chunk, 1)
    B4 = Bm.reshape(BH, nc, chunk, N)
    C4 = Cm.reshape(BH, nc, chunk, N)

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bh, c: (bh, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda bh, c: (bh, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc, chunk, P), xt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt4, da4, B4, C4)
    return out.reshape(BH, L, P)
