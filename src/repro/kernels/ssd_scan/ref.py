"""Pure-jnp oracle: the model's chunked SSD (repro.models.ssm)."""
from __future__ import annotations


from repro.models.ssm import ssd_chunked


def ssd_scan_ref(xt, da, Bm, Cm, chunk: int = 256):
    """Same (BH, L, ...) flat layout as the kernel."""
    BH, L, P = xt.shape
    y, _ = ssd_chunked(xt[:, :, None, :],          # (BH, L, 1, P): H folded
                       da[:, :, None],
                       Bm, Cm, chunk)
    return y[:, :, 0, :]
