"""Jit wrapper matching the model's (B, L, H, P) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_apply(xt, da, Bm, Cm, chunk: int = 256, interpret: bool = False):
    """xt: (B, L, H, P); da: (B, L, H); Bm/Cm: (B, L, N) (groups=1).
    Returns y: (B, L, H, P)."""
    B, L, H, P = xt.shape
    N = Bm.shape[-1]
    xt_f = jnp.moveaxis(xt, 2, 1).reshape(B * H, L, P)
    da_f = jnp.moveaxis(da, 2, 1).reshape(B * H, L)
    B_f = jnp.repeat(Bm[:, None], H, axis=1).reshape(B * H, L, N)
    C_f = jnp.repeat(Cm[:, None], H, axis=1).reshape(B * H, L, N)
    y = ssd_scan(xt_f, da_f, B_f, C_f, chunk=chunk, interpret=interpret)
    return jnp.moveaxis(y.reshape(B, H, L, P), 1, 2)
