"""Causal flash attention Pallas TPU kernel (GQA-aware).

Online-softmax over KV blocks with (m, l, acc) VMEM scratch carried across
the innermost grid axis. Strictly-future KV blocks are skipped with
@pl.when (no MXU work); the diagonal block applies the elementwise causal
mask. This is the TPU hot path for the jnp chunked-attention oracle in
repro.models.layers.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki <= qi)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[0].astype(jnp.float32)               # (bk, D)
        v = v_ref[0].astype(jnp.float32)               # (bk, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

        # elementwise causal mask — only the diagonal block needs it
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + qi * block_q
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + ki * block_k
        s = jnp.where(jnp.logical_or(ki < qi, rows >= cols), s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    n_q_heads: int, n_kv_heads: int,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Causal self-attention. q: (B·Hq, S, D); k, v: (B·Hkv, S, D) — heads
    flattened row-major (batch-major). Returns (B·Hq, S, D)."""
    BH, S, D = q.shape
    assert S % block_q == 0 and S % block_k == 0
    group = n_q_heads // n_kv_heads
    scale = 1.0 / math.sqrt(D)
    grid = (BH, S // block_q, S // block_k)

    def kv_index(bh, qi, ki):
        b = bh // n_q_heads
        h = (bh % n_q_heads) // group
        return (b * n_kv_heads + h, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=S // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
