"""Pure-jnp oracle: dense causal GQA attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, n_q_heads: int, n_kv_heads: int):
    """Same flattened (B·H, S, D) layout as the kernel."""
    BHq, S, D = q.shape
    B = BHq // n_q_heads
    group = n_q_heads // n_kv_heads
    qb = q.reshape(B, n_kv_heads, group, S, D)
    kb = k.reshape(B, n_kv_heads, S, D)
    vb = v.reshape(B, n_kv_heads, S, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bkgsd,bktd->bkgst", qb, kb,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vb)
    return o.reshape(BHq, S, D)
