"""Jit wrapper matching the model's (B, S, H, D) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def flash_attention_bshd(q, k, v, block_q: int = 256, block_k: int = 256,
                         interpret: bool = False):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D), causal."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    qf = jnp.moveaxis(q, 2, 1).reshape(B * Hq, S, D)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, S, D)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, S, D)
    of = flash_attention(qf, kf, vf, n_q_heads=Hq, n_kv_heads=Hkv,
                         block_q=block_q, block_k=block_k,
                         interpret=interpret)
    return jnp.moveaxis(of.reshape(B, Hq, S, D), 1, 2)
