"""Paged-attention decode Pallas TPU kernel.

One query token per sequence attends over its paged KV cache *in place*:
each sequence's block table (the logical-block -> arena-block map kept by
the serving allocator) is scalar-prefetched into SMEM together with the
per-sequence KV lengths, and the grid's innermost axis walks the table,
DMA-ing K/V arena blocks straight into VMEM — the `(B, max_blocks *
block_size, n_kv, D)` logical view that ``repro.models.layers.paged_gather``
materializes per layer per tick is never built.

Grid: ``(B, n_kv_heads, max_blocks)``. Each program handles one
sequence's GQA head-group (the ``group = n_q // n_kv`` query heads that
share a KV head) against one KV block, carrying a flash-style online
softmax in (m, l, acc) VMEM scratch across the block axis. Blocks wholly
past the sequence length are skipped with ``@pl.when`` (their index-map
entry is clamped so the revisit-detection DMA elides the copy), and the
tail block masks columns ``>= length`` to -inf before the running max.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, block_size: int,
            max_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * block_size < length)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale       # (group, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)      # (bs, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (group, bs)

        # length mask: decode attends to kv positions [0, length) only —
        # the tail block's unwritten rows get -inf (exact-0 after exp)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * block_size
        s = jnp.where(cols < length, s, NEG_INF)

        m_prev = m_ref[...]                            # (group, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == max_blocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """Decode attention over a paged KV arena, gathering inside the kernel.

    q: (B, n_q, D) — one query token per sequence;
    k_arena, v_arena: (n_blocks + 1, block_size, n_kv, D) — the shared
    paged pool (last block is the allocator's scratch block);
    block_tables: (B, max_blocks) int32 — arena block per logical block;
    lengths: (B,) int32 — valid KV positions per sequence (entries past
    ``lengths[b]`` are masked; rows whose tables point at scratch simply
    produce ignored-but-finite outputs, exactly like the gather path).
    Returns (B, n_q, D).
    """
    B, n_q, D = q.shape
    block_size, n_kv = k_arena.shape[1], k_arena.shape[2]
    max_blocks = block_tables.shape[1]
    group = n_q // n_kv
    scale = 1.0 / math.sqrt(D)
    grid = (B, n_kv, max_blocks)

    def kv_index(b, h, j, tbl, lens):
        # out-of-length steps are compute-skipped; clamping them onto the
        # sequence's first block lets consecutive skipped steps reuse the
        # resident VMEM copy instead of DMA-ing dead blocks
        blk = jnp.where(j * block_size < lens[b], tbl[b, j], tbl[b, 0])
        return (blk, 0, h, 0)

    kernel = functools.partial(_kernel, scale=scale, block_size=block_size,
                               max_blocks=max_blocks)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, group, D),
                             lambda b, h, j, tbl, lens: (b, h, 0)),
                pl.BlockSpec((1, block_size, 1, D), kv_index),
                pl.BlockSpec((1, block_size, 1, D), kv_index),
            ],
            out_specs=pl.BlockSpec((1, group, D),
                                   lambda b, h, j, tbl, lens: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_q, D), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_arena, v_arena)
