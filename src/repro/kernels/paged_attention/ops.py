"""Jit wrapper matching the decode step's (B, 1, H, D) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import counters
from repro.kernels.paged_attention.kernel import paged_attention


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_decode(q, k_arena, v_arena, block_tables, lengths,
                           interpret: bool = False):
    """q: (B, 1, Hq, D); k/v arena: (n_blocks + 1, bs, Hkv, D);
    block_tables: (B, max_blocks); lengths: (B,) -> (B, 1, Hq, D)."""
    counters.record("paged_attention")
    B, S, Hq, D = q.shape
    assert S == 1, f"paged_attention is decode-only (S=1), got S={S}"
    of = paged_attention(q[:, 0], k_arena, v_arena,
                         jnp.asarray(block_tables, jnp.int32),
                         jnp.asarray(lengths, jnp.int32),
                         interpret=interpret)
    return of[:, None]
