"""Pure-jnp oracle: the serving gather path, standalone.

Mirrors what ``repro.models.layers.apply_attention`` does on the paged
branch at decode time — materialize each sequence's logical KV view via
its block table, then run one masked fp32 softmax over the full view
width. The kernel must match this to flash-attention tolerances (the
online softmax reassociates the fp32 accumulation, nothing else).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q: jax.Array, k_arena: jax.Array,
                        v_arena: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """q: (B, n_q, D); arenas (n_blocks + 1, bs, n_kv, D);
    block_tables (B, max_blocks); lengths (B,). Returns (B, n_q, D)."""
    B, n_q, D = q.shape
    bs, n_kv = k_arena.shape[1], k_arena.shape[2]
    M = block_tables.shape[1]
    group = n_q // n_kv
    scale = 1.0 / math.sqrt(D)

    def view(arena):
        flat = arena.reshape(arena.shape[0] * bs, n_kv, D)
        rows = (block_tables[:, :, None] * bs
                + jnp.arange(bs, dtype=jnp.int32)[None, None, :])
        return flat[rows.reshape(B, M * bs)]            # (B, M*bs, nkv, D)

    k, v = view(k_arena), view(v_arena)
    qg = q.reshape(B, n_kv, group, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(M * bs, dtype=jnp.int32)[None, :]
    valid = kv_pos < lengths[:, None]                   # (B, M*bs)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(B, n_q, D)
