"""Fused Wanda-metric reduction kernel (the Mosaic RC hot loop).

Computes, in one pass over the weight tiles, per-tile partial sums of
ω = |W|·||A||₂ (pass 1) or partial outlier counts ω > threshold (pass 2).
Eq. 5/6 over a projection never materialises the full metric tensor in
HBM: tiles stream HBM->VMEM once, the VPU does |·|·scale + reduce in
registers. Grid = (K-blocks, N-blocks); partials land in a tiny
(gK, gN) array reduced by the caller.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, a_ref, o_ref, *, threshold: Optional[float]):
    w = jnp.abs(w_ref[...].astype(jnp.float32))
    metric = w * a_ref[...].astype(jnp.float32)       # (bk, bn), a: (bk, 1)
    if threshold is None:
        o_ref[0, 0] = jnp.sum(metric)
    else:
        o_ref[0, 0] = jnp.sum((metric > threshold).astype(jnp.float32))


def wanda_partials(w: jax.Array, anorm: jax.Array,
                   threshold: Optional[float] = None, *,
                   block_k: int = 256, block_n: int = 256,
                   interpret: bool = False) -> jax.Array:
    """w: (K, N), anorm: (K,). Returns (K/bk, N/bn) partial sums/counts."""
    K, N = w.shape
    block_k = min(block_k, K)
    block_n = min(block_n, N)
    assert K % block_k == 0 and N % block_n == 0
    grid = (K // block_k, N // block_n)
    kernel = functools.partial(_kernel, threshold=threshold)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, block_n), lambda k, n: (k, n)),
            pl.BlockSpec((block_k, 1), lambda k, n: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda k, n: (k, n)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.float32),
        interpret=interpret,
    )(w, anorm.reshape(-1, 1))
