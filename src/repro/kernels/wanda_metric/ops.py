"""Two-pass fused outlier-ratio op built on the Pallas partial kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wanda_metric.kernel import wanda_partials


@functools.partial(jax.jit, static_argnames=("alpha", "block_k", "block_n",
                                             "interpret"))
def outlier_ratio(w: jax.Array, anorm: jax.Array, alpha: float = 5.0,
                  block_k: int = 256, block_n: int = 256,
                  interpret: bool = False) -> jax.Array:
    """Eq. 6 outlier percentage for one projection, fused on-chip."""
    total = jnp.sum(wanda_partials(w, anorm, None, block_k=block_k,
                                   block_n=block_n, interpret=interpret))
    mean = total / w.size
    thresh = jnp.maximum(alpha * mean, 1e-30)
    count = jnp.sum(_count(w, anorm, thresh, block_k, block_n, interpret))
    return 100.0 * count / w.size


def _count(w, anorm, thresh, block_k, block_n, interpret):
    # threshold is dynamic: fold it into anorm scaling (metric > t  <=>
    # |W|*(anorm/t) > 1), so the kernel's static threshold stays 1.0.
    scaled = anorm / thresh
    return wanda_partials(w, scaled, 1.0, block_k=block_k, block_n=block_n,
                          interpret=interpret)
