"""Pure-jnp oracle for the fused Wanda-metric reduction."""
from __future__ import annotations

import jax.numpy as jnp


def metric_ref(w, anorm):
    return jnp.abs(w.astype(jnp.float32)) * anorm.astype(jnp.float32)[:, None]


def metric_sum_ref(w, anorm):
    return jnp.sum(metric_ref(w, anorm))


def outlier_count_ref(w, anorm, threshold: float):
    return jnp.sum((metric_ref(w, anorm) > threshold).astype(jnp.float32))


def outlier_ratio_ref(w, anorm, alpha: float):
    m = metric_ref(w, anorm)
    return 100.0 * jnp.mean((m > alpha * jnp.mean(m)).astype(jnp.float32))
