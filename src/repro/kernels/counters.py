"""Kernel launch counters (benchmark/CI instrumentation).

Each public kernel op records a launch *at Python dispatch time*, before
entering its jitted body — so eager callers (the benchmarks) count real
dispatches, while a call traced inside an outer ``jax.jit`` counts once
per trace (the launch structure baked into the compiled program). The
MoE kernel benchmark uses this to show the grouped kernel issuing one
launch per projection where the per-expert loop issues E.
"""
from __future__ import annotations

from collections import Counter

_LAUNCHES: Counter = Counter()


def record(kernel: str, n: int = 1) -> None:
    _LAUNCHES[kernel] += n


def reset() -> None:
    _LAUNCHES.clear()


def snapshot() -> dict:
    """{kernel name: launches since the last reset}."""
    return dict(_LAUNCHES)
