"""Kernel launch counters (benchmark/CI instrumentation).

Each public kernel op records a launch *at Python dispatch time*, before
entering its jitted body — so eager callers (the benchmarks) count real
dispatches, while a call traced inside an outer ``jax.jit`` counts once
per trace (the launch structure baked into the compiled program). The
MoE kernel benchmark uses this to show the grouped kernel issuing one
launch per projection where the per-expert loop issues E.

Occupancy-aware dispatches additionally record *work* counters —
``<kernel>_experts_computed`` accumulates how many experts actually got
tile work per launch (``record_concrete``), so benchmarks can pin
"experts computed tracks router occupancy, not E". Work counters only
accumulate when the occupancy value is concrete (eager dispatch); a
traced value inside an outer ``jax.jit`` is silently skipped — the
launch structure is still counted, the data-dependent occupancy is not
knowable at trace time.
"""
from __future__ import annotations

from collections import Counter

import jax

_LAUNCHES: Counter = Counter()


def record(kernel: str, n: int = 1) -> None:
    _LAUNCHES[kernel] += n


def record_concrete(kernel: str, value) -> bool:
    """Accumulate a data-dependent work value (e.g. experts computed in
    an occupancy-aware launch) when it is concrete. Returns True when
    recorded, False when ``value`` was a tracer (outer-jit dispatch)."""
    if isinstance(value, jax.core.Tracer):
        return False
    _LAUNCHES[kernel] += int(value)
    return True


def reset() -> None:
    _LAUNCHES.clear()


def snapshot() -> dict:
    """{kernel name: launches since the last reset}."""
    return dict(_LAUNCHES)
