"""Jit-ready wrapper for the grouped (all-experts-in-one-launch)
block-sparse GEMM, plus plan stacking from independent per-expert plans.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels import counters
from repro.kernels.grouped_block_sparse.kernel import \
    grouped_block_sparse_matmul


def stack_expert_plans(counts_e, indices_e) -> tuple:
    """Stack per-expert ``plan_blocks`` outputs into the rectangular
    (counts (E, nN), indices (E, nN, max_nnz)) arrays the grouped kernel
    consumes: index rows are edge-padded to the max ``max_nnz`` across
    experts (padded steps are masked on ``counts``)."""
    counts_e = [np.asarray(c) for c in counts_e]
    indices_e = [np.asarray(i) for i in indices_e]
    max_nnz = max(idx.shape[1] for idx in indices_e)
    indices_e = [np.pad(idx, ((0, 0), (0, max_nnz - idx.shape[1])),
                        mode="edge") for idx in indices_e]
    return np.stack(counts_e), np.stack(indices_e)


# Above this many slot rows the x panel stops fitting comfortably in
# VMEM next to the weight tiles; fall back to tiling M by the plan block.
PANEL_ROWS_MAX = 1024


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _grouped_matmul_jit(x, w, counts, indices, block_m, block_k, block_n,
                        interpret):
    return grouped_block_sparse_matmul(x, w, counts, indices,
                                       block_m=block_m, block_k=block_k,
                                       block_n=block_n, interpret=interpret)


def grouped_blocksparse_matmul(x, w, counts, indices, block_m=None,
                               block_k=128, block_n=128, interpret=False):
    """Public op: y[e] = x[e] @ w[e] for all experts in one launch,
    visiting nonzero weight blocks only. ``block_m=None`` keeps each
    expert's whole M panel resident (the decode-shaped default — every
    weight tile is read exactly once per launch); pass an explicit
    ``block_m`` to tile M for prefill-sized batches."""
    if block_m is None:
        block_m = x.shape[1]
    counters.record("grouped_block_sparse")
    return _grouped_matmul_jit(x, w, counts, indices, block_m, block_k,
                               block_n, interpret)
