"""Jit-ready wrappers for the grouped (all-experts-in-one-launch) and
ragged (routed-tokens-only) block-sparse GEMMs, plus plan stacking from
independent per-expert plans.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import counters
from repro.kernels.grouped_block_sparse.kernel import (
    grouped_block_sparse_matmul, quant_grouped_block_sparse_matmul,
    quant_ragged_block_sparse_matmul, ragged_block_sparse_matmul)


def stack_expert_plans(counts_e, indices_e) -> tuple:
    """Stack per-expert ``plan_blocks`` outputs into the rectangular
    (counts (E, nN), indices (E, nN, max_nnz)) arrays the grouped kernel
    consumes: index rows are edge-padded to the max ``max_nnz`` across
    experts (padded steps are masked on ``counts``)."""
    counts_e = [np.asarray(c) for c in counts_e]
    indices_e = [np.asarray(i) for i in indices_e]
    max_nnz = max(idx.shape[1] for idx in indices_e)
    indices_e = [np.pad(idx, ((0, 0), (0, max_nnz - idx.shape[1])),
                        mode="edge") for idx in indices_e]
    return np.stack(counts_e), np.stack(indices_e)


# Above this many slot rows the x panel stops fitting comfortably in
# VMEM next to the weight tiles; fall back to tiling M by the plan block.
PANEL_ROWS_MAX = 1024

# M-tile height of the ragged kernel: one sublane tile (covers bf16's
# (16, 128) and f32's (8, 128)), so per-expert segment padding wastes at
# most 15 rows per occupied expert.
RAGGED_BLOCK_ROWS = 16


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _grouped_matmul_jit(x, w, counts, indices, work, block_m, block_k,
                        block_n, interpret):
    return grouped_block_sparse_matmul(x, w, counts, indices, work=work,
                                       block_m=block_m, block_k=block_k,
                                       block_n=block_n, interpret=interpret)


def grouped_blocksparse_matmul(x, w, counts, indices, block_m=None,
                               block_k=128, block_n=128, interpret=False,
                               row_live=None):
    """Public op: y[e] = x[e] @ w[e] for all experts in one launch,
    visiting nonzero weight blocks only. ``block_m=None`` keeps each
    expert's whole M panel resident (the decode-shaped default — every
    weight tile is read exactly once per launch); pass an explicit
    ``block_m`` to tile M for prefill-sized batches.

    ``row_live`` (optional, (E, M) bool): per-row occupancy from the
    router. (expert, M-block) pairs with no live row skip compute and
    elide their DMAs; rows routing later gathers stay bitwise-identical
    to the unmasked launch. None computes every block."""
    if block_m is None:
        block_m = x.shape[1]
    E = x.shape[0]
    n_mblocks = x.shape[1] // block_m
    if row_live is None:
        work = jnp.ones((E, n_mblocks), jnp.int32)
        experts_computed = E
    else:
        work = row_live.reshape(E, n_mblocks, block_m).any(-1)
        experts_computed = work.any(-1).sum()
        work = work.astype(jnp.int32)
    counters.record("grouped_block_sparse")
    counters.record_concrete("grouped_block_sparse_experts_computed",
                             experts_computed)
    return _grouped_matmul_jit(x, w, counts, indices, work, block_m,
                               block_k, block_n, interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _ragged_matmul_jit(x, w, counts, indices, tile_expert, block_m, block_k,
                       block_n, interpret):
    return ragged_block_sparse_matmul(x, w, counts, indices, tile_expert,
                                      block_m=block_m, block_k=block_k,
                                      block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _quant_grouped_matmul_jit(x, tiles, counts, indices, slots, scales,
                              work, block_m, block_k, block_n, interpret):
    return quant_grouped_block_sparse_matmul(
        x, tiles, counts, indices, slots, scales, work=work,
        block_m=block_m, block_k=block_k, block_n=block_n,
        interpret=interpret)


def quant_grouped_blocksparse_matmul(x, tiles, counts, indices, slots,
                                     scales, block_m=None, block_k=128,
                                     block_n=128, interpret=False,
                                     row_live=None):
    """Public op: the grouped launch with kept tiles stored int8 + pow2
    scales (same panel default and ``row_live`` occupancy masking as
    :func:`grouped_blocksparse_matmul`)."""
    if block_m is None:
        block_m = x.shape[1]
    E = x.shape[0]
    n_mblocks = x.shape[1] // block_m
    if row_live is None:
        work = jnp.ones((E, n_mblocks), jnp.int32)
        experts_computed = E
    else:
        work = row_live.reshape(E, n_mblocks, block_m).any(-1)
        experts_computed = work.any(-1).sum()
        work = work.astype(jnp.int32)
    counters.record("grouped_block_sparse_quant")
    counters.record_concrete("grouped_block_sparse_quant_experts_computed",
                             experts_computed)
    return _quant_grouped_matmul_jit(x, tiles, counts, indices, slots,
                                     scales, work, block_m, block_k,
                                     block_n, interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _quant_ragged_matmul_jit(x, tiles, counts, indices, slots, scales,
                             tile_expert, block_m, block_k, block_n,
                             interpret):
    return quant_ragged_block_sparse_matmul(
        x, tiles, counts, indices, slots, scales, tile_expert,
        block_m=block_m, block_k=block_k, block_n=block_n,
        interpret=interpret)


def quant_ragged_blocksparse_matmul(x, tiles, counts, indices, slots,
                                    scales, tile_expert,
                                    block_m=RAGGED_BLOCK_ROWS, block_k=128,
                                    block_n=128, interpret=False):
    """Public op: the ragged routed-tokens-only launch with kept tiles
    stored int8 + pow2 scales."""
    counters.record("grouped_block_sparse_ragged_quant")
    E = counts.shape[0]
    live = tile_expert >= 0
    occupied = (jnp.zeros((E,), jnp.int32)
                .at[jnp.maximum(tile_expert, 0)]
                .max(live.astype(jnp.int32)).sum())
    counters.record_concrete(
        "grouped_block_sparse_ragged_quant_experts_computed", occupied)
    return _quant_ragged_matmul_jit(x, tiles, counts, indices, slots,
                                    scales, tile_expert.astype(jnp.int32),
                                    block_m, block_k, block_n, interpret)


def ragged_blocksparse_matmul(x, w, counts, indices, tile_expert,
                              block_m=RAGGED_BLOCK_ROWS, block_k=128,
                              block_n=128, interpret=False):
    """Public op: the ragged expert batch (routed tokens packed into
    ``block_m``-aligned per-expert segments) through every owning
    expert's tile plan, one launch, M-grid sized by the packed buffer
    rather than E·capacity. ``tile_expert`` maps each M-tile to its
    expert (``-1`` = dead padding tile, skipped)."""
    counters.record("grouped_block_sparse_ragged")
    E = w.shape[0]
    live = tile_expert >= 0
    occupied = (jnp.zeros((E,), jnp.int32)
                .at[jnp.maximum(tile_expert, 0)]
                .max(live.astype(jnp.int32)).sum())
    counters.record_concrete("grouped_block_sparse_ragged_experts_computed",
                             occupied)
    return _ragged_matmul_jit(x, w, counts, indices,
                              tile_expert.astype(jnp.int32), block_m,
                              block_k, block_n, interpret)
