"""Pure-jnp oracle for the grouped block-sparse GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_block_sparse_matmul_ref(x: jax.Array, w: jax.Array,
                                    block_masks: jax.Array, block_k: int,
                                    block_n: int) -> jax.Array:
    """y[e] = x[e] @ (w[e] with pruned blocks zeroed).

    x: (E, M, K); w: (E, K, N); block_masks: (E, K/bk, N/bn).
    """
    mask = jnp.repeat(jnp.repeat(block_masks, block_k, axis=1),
                      block_n, axis=2)
    return jnp.einsum("emk,ekn->emn", x, jnp.where(mask, w,
                                                   jnp.zeros_like(w)))


def ragged_block_sparse_matmul_ref(x: jax.Array, w: jax.Array,
                                   tile_expert, block_m: int,
                                   block_masks: jax.Array, block_k: int,
                                   block_n: int) -> jax.Array:
    """Oracle for the ragged kernel: each ``block_m``-row tile of the
    packed buffer times its owning expert's (mask-zeroed) weight; dead
    tiles (``tile_expert < 0``) produce zero rows.

    x: (M, K); w: (E, K, N); tile_expert: (M/bm,);
    block_masks: (E, K/bk, N/bn).
    """
    mask = jnp.repeat(jnp.repeat(block_masks, block_k, axis=1),
                      block_n, axis=2)
    wm = jnp.where(mask, w, jnp.zeros_like(w))
    tiles = []
    for t in range(x.shape[0] // block_m):
        e = int(tile_expert[t])
        xt = x[t * block_m:(t + 1) * block_m]
        if e < 0:
            tiles.append(jnp.zeros((block_m, w.shape[2]), x.dtype))
        else:
            tiles.append(xt @ wm[e])
    return jnp.concatenate(tiles, axis=0)
