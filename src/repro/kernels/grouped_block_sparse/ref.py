"""Pure-jnp oracle for the grouped block-sparse GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_block_sparse_matmul_ref(x: jax.Array, w: jax.Array,
                                    block_masks: jax.Array, block_k: int,
                                    block_n: int) -> jax.Array:
    """y[e] = x[e] @ (w[e] with pruned blocks zeroed).

    x: (E, M, K); w: (E, K, N); block_masks: (E, K/bk, N/bn).
    """
    mask = jnp.repeat(jnp.repeat(block_masks, block_k, axis=1),
                      block_n, axis=2)
    return jnp.einsum("emk,ekn->emn", x, jnp.where(mask, w,
                                                   jnp.zeros_like(w)))
