"""Grouped block-sparse GEMM Pallas TPU kernel — all MoE experts' pruned
projection matmuls in ONE launch (MegaBlocks-style).

The per-expert serving path issues E separate ``block_sparse`` launches
per projection, serializing dispatch and leaving the MXU idle between
experts. Here the expert axis joins the grid instead: grid =
(E, M-blocks, N-blocks, max_nnz), and each program scalar-prefetches its
*own expert's* nonzero K-block indices from the stacked plan
(``counts (E, N/bn)``, ``indices (E, N/bn, max_nnz)``). Tile skips
compose across experts — a zero tile costs nothing no matter which
expert owns it — and the whole expert group pays one dispatch
round-trip. Experts share ``max_nnz`` (index rows are edge-padded by
``pack_expert_projection``; padded steps are masked on ``counts``), so
a denser expert never starves a sparser one of grid steps it needs.

Unlike the dense-weight kernel, ``block_m`` here usually covers the
*whole* per-expert slot batch (the ops wrapper's panel default): each
expert's capacity-slot batch is small at decode time (C·G rows), so the
x panel stays resident while the grid walks that expert's nonzero
(K-block, N-block) tiles — each weight tile is then touched exactly
once per launch instead of once per M-block, the MegaBlocks layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(count_ref, idx_ref, x_ref, w_ref, o_ref, acc_ref, *,
            max_nnz: int):
    e = pl.program_id(0)
    n = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < count_ref[e, n])
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_block_sparse_matmul(x: jax.Array, w: jax.Array,
                                counts: jax.Array, indices: jax.Array, *,
                                block_m: int = 128, block_k: int = 128,
                                block_n: int = 128,
                                interpret: bool = False) -> jax.Array:
    """y[e] = x[e] @ w[e] for every expert e, one kernel launch total,
    visiting only each expert's nonzero (K-block, N-block) weight tiles.

    x: (E, M, K) — per-expert capacity-slot batches;
    w: (E, K, N) — expert weight stack (zeros in pruned blocks);
    counts: (E, N/bn) int32 — nonzero K-blocks per expert/block-column;
    indices: (E, N/bn, max_nnz) int32 — their K-block ids (edge-padded to
    the shared max_nnz so the stack is rectangular).
    """
    E, M, K = x.shape
    E2, K2, N = w.shape
    assert E == E2 and K == K2
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    assert counts.shape == (E, N // block_n)
    max_nnz = indices.shape[-1]

    grid = (E, M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_m, block_k),
                             lambda e, m, n, s, cnt, idx: (e, m, idx[e, n, s])),
                pl.BlockSpec((1, block_k, block_n),
                             lambda e, m, n, s, cnt, idx: (e, idx[e, n, s], n)),
            ],
            out_specs=pl.BlockSpec((1, block_m, block_n),
                                   lambda e, m, n, s, cnt, idx: (e, m, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, x, w)
