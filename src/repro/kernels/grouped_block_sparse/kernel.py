"""Grouped block-sparse GEMM Pallas TPU kernels — all MoE experts' pruned
projection matmuls in ONE launch (MegaBlocks-style).

The per-expert serving path issues E separate ``block_sparse`` launches
per projection, serializing dispatch and leaving the MXU idle between
experts. Here the expert axis joins the grid instead: grid =
(E, M-blocks, N-blocks, max_nnz), and each program scalar-prefetches its
*own expert's* nonzero K-block indices from the stacked plan
(``counts (E, N/bn)``, ``indices (E, N/bn, max_nnz)``). Tile skips
compose across experts — a zero tile costs nothing no matter which
expert owns it — and the whole expert group pays one dispatch
round-trip. Experts share ``max_nnz`` (index rows are edge-padded by
``pack_expert_projection``; padded steps are masked on ``counts``), so
a denser expert never starves a sparser one of grid steps it needs.

Unlike the dense-weight kernel, ``block_m`` here usually covers the
*whole* per-expert slot batch (the ops wrapper's panel default): each
expert's capacity-slot batch is small at decode time (C·G rows), so the
x panel stays resident while the grid walks that expert's nonzero
(K-block, N-block) tiles — each weight tile is then touched exactly
once per launch instead of once per M-block, the MegaBlocks layout.

Two occupancy-aware refinements on top (router counts prefetched
alongside the tile plan):

* **Masked grid** (:func:`grouped_block_sparse_matmul` with a ``work``
  array): a third scalar-prefetch arg ``work (E, M/bm)`` marks which
  per-expert M-blocks hold any routed token. Dead (expert, M-block)
  pairs skip the MXU entirely and clamp their x/w index maps to the
  step-0 block — consecutive grid steps then revisit the same block and
  the DMA is elided, exactly paged_attention's dead-block idiom. Output
  blocks are still flushed (zeros), so results are bitwise-identical to
  the unmasked launch on every row routing later gathers.

* **Ragged grid** (:func:`ragged_block_sparse_matmul`): the E axis
  leaves the grid entirely. Routed tokens are packed into one
  contiguous ``(M, K)`` buffer of ``block_m``-aligned per-expert
  segments, and a prefetched ``tile_expert (M/bm,)`` map (from the
  cumsum of router counts; ``-1`` = past-the-end padding) tells each
  M-tile which expert's plan and weights it runs. The grid is
  (M/bm, N-blocks, max_nnz) — proportional to tokens actually routed,
  not E·capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(count_ref, idx_ref, work_ref, x_ref, w_ref, o_ref, acc_ref, *,
            max_nnz: int):
    e = pl.program_id(0)
    m = pl.program_id(1)
    n = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((s < count_ref[e, n]) & (work_ref[e, m] > 0))
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_block_sparse_matmul(x: jax.Array, w: jax.Array,
                                counts: jax.Array, indices: jax.Array, *,
                                work: jax.Array | None = None,
                                block_m: int = 128, block_k: int = 128,
                                block_n: int = 128,
                                interpret: bool = False) -> jax.Array:
    """y[e] = x[e] @ w[e] for every expert e, one kernel launch total,
    visiting only each expert's nonzero (K-block, N-block) weight tiles.

    x: (E, M, K) — per-expert capacity-slot batches;
    w: (E, K, N) — expert weight stack (zeros in pruned blocks);
    counts: (E, N/bn) int32 — nonzero K-blocks per expert/block-column;
    indices: (E, N/bn, max_nnz) int32 — their K-block ids (edge-padded to
    the shared max_nnz so the stack is rectangular);
    work: optional (E, M/bm) int32 — occupancy per (expert, M-block);
    zero entries skip compute and elide DMAs (their output blocks flush
    as zeros). None computes every block (all-occupied).
    """
    E, M, K = x.shape
    E2, K2, N = w.shape
    assert E == E2 and K == K2
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    assert counts.shape == (E, N // block_n)
    max_nnz = indices.shape[-1]
    if work is None:
        work = jnp.ones((E, M // block_m), jnp.int32)
    assert work.shape == (E, M // block_m)

    def x_map(e, m, n, s, cnt, idx, wrk):
        # dead (e, m)-blocks pin the K-block to the step-0 one so every
        # later step revisits it and the DMA is elided
        return (e, m, jnp.where(wrk[e, m] > 0, idx[e, n, s], idx[e, n, 0]))

    def w_map(e, m, n, s, cnt, idx, wrk):
        return (e, jnp.where(wrk[e, m] > 0, idx[e, n, s], idx[e, n, 0]), n)

    grid = (E, M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_m, block_k), x_map),
                pl.BlockSpec((1, block_k, block_n), w_map),
            ],
            out_specs=pl.BlockSpec((1, block_m, block_n),
                                   lambda e, m, n, s, cnt, idx, wrk:
                                   (e, m, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, work, x, w)


def _quant_kernel(count_ref, idx_ref, slot_ref, scale_ref, work_ref, x_ref,
                  w_ref, o_ref, acc_ref, *, max_nnz: int):
    e = pl.program_id(0)
    m = pl.program_id(1)
    n = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((s < count_ref[e, n]) & (work_ref[e, m] > 0))
    def _accum():
        # pow2 per-tile scale on the accumulated product: bitwise-equal
        # to the unquantized kernel over the fake-quant weight stack
        acc_ref[...] += jnp.dot(x_ref[0], w_ref[0].astype(x_ref.dtype),
                                preferred_element_type=jnp.float32
                                ) * scale_ref[e, n, s]

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def quant_grouped_block_sparse_matmul(x: jax.Array, tiles: jax.Array,
                                      counts: jax.Array,
                                      indices: jax.Array,
                                      slots: jax.Array,
                                      scales: jax.Array, *,
                                      work: jax.Array | None = None,
                                      block_m: int = 128,
                                      block_k: int = 128,
                                      block_n: int = 128,
                                      interpret: bool = False) -> jax.Array:
    """The grouped launch over int8 kept-tile storage.

    Same grid and occupancy masking as
    :func:`grouped_block_sparse_matmul`, but the dense ``(E, K, N)``
    weight stack is replaced by ``tiles`` — every expert's kept tiles
    concatenated in plan order into one ``(T, block_k, block_n)`` int8
    array — with ``slots (E, N/bn, max_nnz)`` holding *absolute* storage
    rows and ``scales (E, N/bn, max_nnz)`` the per-tile pow2 dequant
    factors, both scalar-prefetched beside the plan.
    """
    E, M, K = x.shape
    assert tiles.shape[1:] == (block_k, block_n)
    N = counts.shape[1] * block_n
    assert M % block_m == 0 and K % block_k == 0
    max_nnz = indices.shape[-1]
    if work is None:
        work = jnp.ones((E, M // block_m), jnp.int32)
    assert work.shape == (E, M // block_m)

    def x_map(e, m, n, s, cnt, idx, slt, scl, wrk):
        return (e, m, jnp.where(wrk[e, m] > 0, idx[e, n, s], idx[e, n, 0]))

    def w_map(e, m, n, s, cnt, idx, slt, scl, wrk):
        return (jnp.where(wrk[e, m] > 0, slt[e, n, s], slt[e, n, 0]), 0, 0)

    grid = (E, M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_quant_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_m, block_k), x_map),
                pl.BlockSpec((1, block_k, block_n), w_map),
            ],
            out_specs=pl.BlockSpec((1, block_m, block_n),
                                   lambda e, m, n, s, cnt, idx, slt, scl,
                                   wrk: (e, m, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, slots, scales, work, x, tiles)


def _quant_ragged_kernel(count_ref, idx_ref, slot_ref, scale_ref, tile_ref,
                         x_ref, w_ref, o_ref, acc_ref, *, max_nnz: int):
    t = pl.program_id(0)
    n = pl.program_id(1)
    s = pl.program_id(2)
    e = tile_ref[t]
    ec = jnp.maximum(e, 0)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((e >= 0) & (s < count_ref[ec, n]))
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0].astype(x_ref.dtype),
                                preferred_element_type=jnp.float32
                                ) * scale_ref[ec, n, s]

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_ragged_block_sparse_matmul(x: jax.Array, tiles: jax.Array,
                                     counts: jax.Array, indices: jax.Array,
                                     slots: jax.Array, scales: jax.Array,
                                     tile_expert: jax.Array, *,
                                     block_m: int = 16, block_k: int = 128,
                                     block_n: int = 128,
                                     interpret: bool = False) -> jax.Array:
    """The ragged routed-tokens-only launch over int8 kept-tile storage
    (``slots``/``scales`` as in :func:`quant_grouped_block_sparse_matmul`;
    dead tiles clamp their slot like they clamp their K-block index)."""
    M, K = x.shape
    assert tiles.shape[1:] == (block_k, block_n)
    E, nN = counts.shape
    N = nN * block_n
    assert M % block_m == 0 and K % block_k == 0
    assert tile_expert.shape == (M // block_m,)
    max_nnz = indices.shape[-1]

    def x_map(t, n, s, cnt, idx, slt, scl, te):
        ec = jnp.maximum(te[t], 0)
        return (t, jnp.where(te[t] >= 0, idx[ec, n, s], idx[ec, n, 0]))

    def w_map(t, n, s, cnt, idx, slt, scl, te):
        ec = jnp.maximum(te[t], 0)
        return (jnp.where(te[t] >= 0, slt[ec, n, s], slt[ec, n, 0]), 0, 0)

    grid = (M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_quant_ragged_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), x_map),
                pl.BlockSpec((1, block_k, block_n), w_map),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda t, n, s, cnt, idx, slt, scl, te:
                                   (t, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, slots, scales, tile_expert, x, tiles)


def _ragged_kernel(count_ref, idx_ref, tile_ref, x_ref, w_ref, o_ref,
                   acc_ref, *, max_nnz: int):
    t = pl.program_id(0)
    n = pl.program_id(1)
    s = pl.program_id(2)
    e = tile_ref[t]
    ec = jnp.maximum(e, 0)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((e >= 0) & (s < count_ref[ec, n]))
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def ragged_block_sparse_matmul(x: jax.Array, w: jax.Array,
                               counts: jax.Array, indices: jax.Array,
                               tile_expert: jax.Array, *,
                               block_m: int = 16, block_k: int = 128,
                               block_n: int = 128,
                               interpret: bool = False) -> jax.Array:
    """y = x @ w[tile_expert] over a ragged expert-packed batch, one
    launch, grid proportional to routed tokens instead of E·capacity.

    x: (M, K) — routed tokens packed into ``block_m``-aligned per-expert
    segments (the MegaBlocks layout; rows past an expert's count are
    zero padding inside its last tile);
    w: (E, K, N) — expert weight stack;
    counts / indices: the stacked tile plan (as in
    :func:`grouped_block_sparse_matmul`);
    tile_expert: (M/bm,) int32 — which expert owns each M-tile, ``-1``
    for dead tiles past the packed total (skipped: no MXU work, index
    maps clamped so their DMAs are elided, output flushed as zeros).
    """
    M, K = x.shape
    E, K2, N = w.shape
    assert K == K2
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    assert counts.shape == (E, N // block_n)
    assert tile_expert.shape == (M // block_m,)
    max_nnz = indices.shape[-1]

    def x_map(t, n, s, cnt, idx, te):
        ec = jnp.maximum(te[t], 0)
        return (t, jnp.where(te[t] >= 0, idx[ec, n, s], idx[ec, n, 0]))

    def w_map(t, n, s, cnt, idx, te):
        ec = jnp.maximum(te[t], 0)
        return (ec, jnp.where(te[t] >= 0, idx[ec, n, s], idx[ec, n, 0]), n)

    grid = (M // block_m, N // block_n, max_nnz)
    kernel = functools.partial(_ragged_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), x_map),
                pl.BlockSpec((1, block_k, block_n), w_map),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda t, n, s, cnt, idx, te: (t, n)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(counts, indices, tile_expert, x, w)
