"""Weight-only quantisation: the Table XIII RTN baseline plus the
per-tile int8 helpers behind the sparse × quantized serving path.

``quantize_array``/``quantize_model`` implement standard group-wise
symmetric round-to-nearest int{8,4,3,2}: each output column quantises
in groups of consecutive *input* rows, so groups never straddle output
columns (the GPTQ/RTN convention). The dequantised model runs through
the normal dense forward — the quality/compression baseline Mosaic is
compared against in the paper's Table XIII.

``quantize_tiles``/``dequantize_tiles`` quantise the *kept* tiles of a
block-sparse plan to int8 with one symmetric power-of-two scale per
tile. A power-of-two scale only shifts exponents, so multiplying by it
commutes with every floating-point rounding in the accumulation. That
is what lets the quantized kernels apply the scale to the *accumulated
tile product* (one multiply per tile) and still be bitwise identical to
running the unquantized kernel over the fake-quant (dequantised)
weights; that identity is the numerics oracle in
``tests/test_quant_kernels.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_get, tree_set
from repro.core.recipe import QUANT_MODES  # noqa: F401  (canonical home)
from repro.core.registry import projections
from repro.models.specs import ModelConfig

INT8_MAXQ = 127


def quantize_array(w: jax.Array, bits: int, group: int = 128):
    """Group-wise symmetric RTN. The weight folds to ``(K, N)`` (input
    rows × flattened outputs); each output column quantises in groups of
    ``group`` consecutive input rows, so groups never straddle column
    boundaries. Returns ``(q, scale, orig_shape, pad)``: ``q`` is
    ``(N, ceil(K/group), group)``, ``scale`` broadcasts against it.
    Invert with :func:`dequantize_array`."""
    orig_shape = w.shape
    w2 = w.astype(jnp.float32).reshape(w.shape[0], -1)         # (K, N)
    pad = (-w2.shape[0]) % group
    cols = jnp.pad(w2, ((0, pad), (0, 0))).T                   # (N, K+pad)
    g = cols.reshape(cols.shape[0], -1, group)
    maxq = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / maxq
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / scale), -maxq - 1, maxq).astype(jnp.int8)
    return q, scale, orig_shape, pad


def dequantize_array(q, scale, orig_shape, pad):
    cols = (q.astype(jnp.float32) * scale).reshape(q.shape[0], -1)
    if pad:
        cols = cols[:, :-pad]
    return cols.T.reshape(orig_shape)


def quantize_model(params, cfg: ModelConfig, bits: int, group: int = 128):
    """Fake-quant every projection (round-trip). Returns (params, stats)."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    dense_bits = 0
    quant_bits = 0
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        q, scale, shape, pad = quantize_array(w, bits, group)
        dense_bits += w.size * 16                          # fp16 reference
        quant_bits += w.size * bits + scale.size * 16
        params = tree_set(params, proj.path,
                          dequantize_array(q, scale, shape, pad).astype(w.dtype))
    stats = {"compression": dense_bits / max(quant_bits, 1), "bits": bits}
    return params, stats


# ------------------------------------------------------------ kept tiles


def quantize_tiles(tiles) -> tuple:
    """Symmetric int8 with one power-of-two scale per tile.

    ``tiles``: (T, bk, bn) float. Returns ``(q, scales)`` with ``q``
    int8 and ``scales`` f32, ``scales[t] = 2^ceil(log2(amax_t / 127))``
    (all-zero tiles get scale 1.0). Rounding the scale *up* to a power
    of two keeps ``|q| <= 127`` and makes dequantisation exact in both
    f32 and bf16 — int8 magnitudes and pow2 factors carry no mantissa
    bits beyond what bf16 holds."""
    t = np.asarray(tiles, np.float32)
    amax = np.max(np.abs(t), axis=(1, 2))
    exp = np.ceil(np.log2(np.maximum(amax, 1e-38) / INT8_MAXQ))
    scales = np.where(amax > 0,
                      np.exp2(np.clip(exp, -126, 126)),
                      1.0).astype(np.float32)
    q = np.clip(np.rint(t / scales[:, None, None]),
                -INT8_MAXQ, INT8_MAXQ).astype(np.int8)
    return q, scales


def dequantize_tiles(q, scales) -> np.ndarray:
    """Exact inverse of the pow2 fake-quant: (T, bk, bn) f32 tiles."""
    return (np.asarray(q, np.float32)
            * np.asarray(scales, np.float32)[:, None, None])
