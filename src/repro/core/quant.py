"""Weight-only quantisation baseline (Table XIII comparison).

Per-group symmetric round-to-nearest int{8,4,3,2} on every projection.
The dequantised model runs through the normal forward — this measures the
quality/compression tradeoff Mosaic is compared against in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.tree import tree_get, tree_set
from repro.core.registry import projections
from repro.models.specs import ModelConfig


def quantize_array(w: jax.Array, bits: int, group: int = 128):
    """Returns (q int8, scales) with per-(group of input rows) scales."""
    orig_shape = w.shape
    flat = w.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % group
    flat = jnp.pad(flat, (0, pad))
    g = flat.reshape(-1, group)
    maxq = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g), axis=1, keepdims=True) / maxq
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / scale), -maxq - 1, maxq).astype(jnp.int8)
    return q, scale, orig_shape, pad


def dequantize_array(q, scale, orig_shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(orig_shape)


def quantize_model(params, cfg: ModelConfig, bits: int, group: int = 128):
    """Fake-quant every projection (round-trip). Returns (params, stats)."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    dense_bits = 0
    quant_bits = 0
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        q, scale, shape, pad = quantize_array(w, bits, group)
        dense_bits += w.size * 16                          # fp16 reference
        quant_bits += w.size * bits + scale.size * 16
        params = tree_set(params, proj.path,
                          dequantize_array(q, scale, shape, pad).astype(w.dtype))
    stats = {"compression": dense_bits / max(quant_bits, 1), "bits": bits}
    return params, stats
