"""Recipe sweeps: profile once, prune many (the paper's E5 reuse win).

The Ranking Controller profiles the model ONCE; the resulting
:class:`~repro.core.rank_controller.RankArtifact` is reused by every
pruning level and category (Fig. 5 / Algorithm 1 — the source of
Mosaic's 7.19x model-production speedup). :func:`run_sweep` turns that
property into a subsystem: one base :class:`~repro.core.recipe.
PruneRecipe` plus a :class:`GridSpec` fan a single profile across a
p-level x category x selector grid, save each point's
:class:`~repro.core.artifact.PrunedArtifact`, evaluate each point's
quality (ppl / acc via the ``evaluate`` stage), and emit a Pareto table
(CSV + markdown) ranking the points by quality-per-byte.

Grid-spec JSON (any subset of axes; omitted axes inherit the base
recipe's value)::

    {"p": [0.3, 0.5, 0.7], "category": ["composite", "unstructured"]}

Output layout (``out_dir``)::

    profile/          # the single RankArtifact (reused, reloadable)
    points/<label>/   # one PrunedArtifact bundle per grid point
    pareto.csv        # one row per point: quality + size + time
    pareto.md         # the same table, human-readable

Re-running a sweep over the same ``out_dir`` resumes: points whose
bundle already exists are skipped (their Pareto row is rebuilt from the
saved ``report.json``) — pass ``resume=False`` / ``--fresh`` to force.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import time
from typing import Callable, Iterable, Optional, Union

from repro.core.artifact import RECIPE_FILE, REPORT_FILE, PrunedArtifact
from repro.core.evaluate import default_eval_batches
from repro.core.pipeline import MosaicPipeline
from repro.core.rank_controller import (RankArtifact, ensure_hessians,
                                        profile_model)
from repro.core.recipe import PruneRecipe
from repro.models.specs import ModelConfig

GRID_AXES = ("p", "category", "selector", "granularity", "quant")

CSV_COLUMNS = ("label", "arch", "p", "category", "selector", "granularity",
               "quant", "ppl", "acc", "bytes_after", "params_after",
               "prune_seconds", "point_seconds", "flop_savings",
               "expert_plans", "quality_per_byte", "pareto")


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The sweep grid: values per recipe axis; empty axis = keep base.
    The ``quant`` axis sweeps precision ("none" / "int8") against the
    same profile, so Pareto rows chart quality-per-byte across
    p × precision."""
    p: tuple = ()
    category: tuple = ()
    selector: tuple = ()
    granularity: tuple = ()
    quant: tuple = ()

    def __post_init__(self):
        for name in GRID_AXES:
            object.__setattr__(self, name, tuple(getattr(self, name)))

    def points(self, base: PruneRecipe) -> list:
        """Cartesian product of the axes, materialised as recipes."""
        axes = [getattr(self, name) or (getattr(base, name),)
                for name in GRID_AXES]
        return [base.replace(**dict(zip(GRID_AXES, combo)))
                for combo in itertools.product(*axes)]

    def n_points(self) -> int:
        n = 1
        for name in GRID_AXES:
            n *= max(len(getattr(self, name)), 1)
        return n

    # ------------------------------------------------------------- codec

    def to_dict(self) -> dict:
        return {name: list(getattr(self, name)) for name in GRID_AXES
                if getattr(self, name)}

    @classmethod
    def from_dict(cls, d: dict) -> "GridSpec":
        unknown = set(d) - set(GRID_AXES)
        if unknown:
            raise ValueError(f"unknown grid axes: {sorted(unknown)}; "
                             f"choices: {GRID_AXES}")
        for k, v in d.items():
            if not isinstance(v, (list, tuple)):
                raise ValueError(f"grid axis {k!r} must be a list of "
                                 f"values, got {v!r}")
        return cls(**{k: tuple(v) for k, v in d.items()})

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "GridSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "GridSpec":
        with open(path) as f:
            return cls.from_json(f.read())


def point_label(recipe: PruneRecipe) -> str:
    """Filesystem-safe grid-point name, e.g. ``p0.5-composite-wanda``."""
    parts = [f"p{recipe.p:g}", recipe.category or "auto", recipe.selector]
    if recipe.granularity != "projection":
        parts.append(recipe.granularity)
    if recipe.quant != "none":
        parts.append(recipe.quant)
    return "-".join(parts)


@dataclasses.dataclass
class SweepResult:
    rows: list                       # one report dict per grid point
    rank_artifact: Optional[RankArtifact]  # the single reused profile
    # (None when every point resumed and no profile was supplied)
    profiled: bool                   # False when the profile was supplied
    out_dir: Optional[str] = None
    csv_path: Optional[str] = None
    md_path: Optional[str] = None


def _resume_report(artifact_dir: Optional[str],
                   point: PruneRecipe) -> Optional[dict]:
    """The saved report of a resumable grid point, or None when the
    point must (re-)execute. The label only encodes p / category /
    selector / granularity, so the bundle's own ``recipe.json`` must
    equal the current point recipe — editing any other base-recipe
    field (block, spread, calibration, ...) invalidates the bundle
    instead of silently serving stale results."""
    if not artifact_dir or not PrunedArtifact.is_artifact(artifact_dir):
        return None
    report_path = os.path.join(artifact_dir, REPORT_FILE)
    if not os.path.exists(report_path):
        return None
    try:
        with open(os.path.join(artifact_dir, RECIPE_FILE)) as f:
            saved = PruneRecipe.from_dict(json.load(f))
        if saved != point:
            return None
        with open(report_path) as f:
            return json.load(f)
    except (OSError, ValueError, TypeError, KeyError):
        return None       # unreadable/truncated/foreign bundle: re-run


def _point_stages(stages: Iterable) -> tuple:
    """Sweep-point stage list: never re-rank; always evaluate + report."""
    ordered = [s for s in stages if s != "rank"]
    if "report" not in ordered:
        ordered.append("report")
    if "evaluate" not in ordered:
        ordered.insert(ordered.index("report"), "evaluate")
    return tuple(ordered)


def run_sweep(base: PruneRecipe,
              grid: Union[GridSpec, Iterable],
              params, cfg: ModelConfig, *,
              out_dir: Optional[str] = None,
              calibration: Optional[list] = None,
              rank_artifact: Optional[RankArtifact] = None,
              eval_batches: Optional[dict] = None,
              resume: bool = True,
              progress: Optional[Callable] = None) -> SweepResult:
    """Profile once, prune many, evaluate every point, rank by Pareto.

    ``grid`` is a :class:`GridSpec` (expanded against ``base``) or an
    explicit iterable of recipes. ``rank_artifact`` skips profiling
    entirely (e.g. a profile loaded from disk); otherwise
    ``profile_model`` runs exactly once for the whole sweep, with
    Hessians only when some point's selector needs them — and a supplied
    Hessian-free profile gains them lazily via :func:`ensure_hessians`.

    ``resume`` (default on): grid points whose ``points/<label>/``
    bundle already exists under ``out_dir`` are not re-pruned — their
    Pareto row is rebuilt from the saved ``report.json``, so an
    interrupted sweep re-run pays only for the missing points. Pass
    ``resume=False`` to force every point to re-execute.
    """
    say = progress or (lambda *_: None)
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    points = grid.points(base) if isinstance(grid, GridSpec) else list(grid)
    if not points:
        raise ValueError("empty sweep grid")
    want_hessians = any(r.selector == "sparsegpt" for r in points)

    def _calibration():
        if calibration is not None:
            return calibration
        from repro.data.pipeline import SyntheticCorpus
        c = base.calibration
        corpus = SyntheticCorpus(cfg.vocab, seed=c.seed)
        return corpus.calibration_batches(c.n_samples, c.batch_size,
                                          c.seq_len)

    profiled = False

    profile_ready = False

    def ensure_profile() -> RankArtifact:
        """Profile (or attach Hessians) on first *executed* point only —
        a fully-resumed sweep re-run never pays the calibration cost."""
        nonlocal rank_artifact, profiled, profile_ready
        if profile_ready:
            return rank_artifact
        if rank_artifact is None:
            say(f"profiling once for {len(points)} points "
                f"(hessians={want_hessians})")
            rank_artifact = profile_model(params, cfg, _calibration(),
                                          want_hessians=want_hessians)
            profiled = True
        elif want_hessians and rank_artifact.hessians is None:
            say("attaching hessians to the supplied profile (lazy)")
            rank_artifact = ensure_hessians(rank_artifact, params, cfg,
                                            _calibration())
        if out_dir:
            rank_artifact.save(os.path.join(out_dir, "profile"))
        profile_ready = True
        return rank_artifact

    if eval_batches is None:
        eval_batches = default_eval_batches(cfg, base)

    rows = []
    labels: dict = {}
    n_resumed = 0
    for recipe in points:
        point = recipe.replace(stages=_point_stages(recipe.stages))
        label = point_label(point)
        if label in labels:                      # duplicate grid points
            labels[label] += 1
            label = f"{label}-{labels[label]}"
        else:
            labels[label] = 0
        artifact_dir = (os.path.join(out_dir, "points", label)
                        if out_dir else None)
        rep = _resume_report(artifact_dir, point) if resume else None
        if rep is not None:
            point_seconds = 0.0
            n_resumed += 1
        else:
            t0 = time.perf_counter()
            artifact = MosaicPipeline(point).run(
                params, cfg, rank_artifact=ensure_profile(),
                eval_batches=eval_batches)
            point_seconds = time.perf_counter() - t0
            if artifact_dir:
                artifact.save(artifact_dir)
            rep = artifact.report
        pack = rep.get("pack") or {}
        rows.append({
            "label": label,
            "arch": point.arch,
            "p": point.p,
            "category": rep.get("category"),
            "selector": point.selector,
            "granularity": point.granularity,
            "quant": rep.get("quant", point.quant),
            "ppl": rep.get("ppl"),
            "acc": rep.get("acc"),
            "bytes_after": rep.get("bytes_after"),
            "params_after": rep.get("params_after"),
            "prune_seconds": rep.get("prune_seconds"),
            "point_seconds": point_seconds,
            "flop_savings": pack.get("flop_savings"),
            "expert_plans": pack.get("n_expert_packed"),
            "artifact_dir": artifact_dir,
        })
        if progress:
            r = rows[-1]
            progress(f"{label}: ppl={_fmt(r, 'ppl')} acc={_fmt(r, 'acc')} "
                     f"bytes={r['bytes_after']} in {point_seconds:.1f}s")
    if n_resumed:
        say(f"resume: skipped {n_resumed}/{len(points)} grid points with "
            f"existing bundles under {os.path.join(out_dir, 'points')}")

    annotate_pareto(rows)
    rows.sort(key=lambda r: -(r["quality_per_byte"] or 0.0))
    result = SweepResult(rows=rows, rank_artifact=rank_artifact,
                         profiled=profiled, out_dir=out_dir)
    if out_dir:
        result.csv_path = os.path.join(out_dir, "pareto.csv")
        result.md_path = os.path.join(out_dir, "pareto.md")
        with open(result.csv_path, "w") as f:
            f.write(pareto_csv(rows))
        with open(result.md_path, "w") as f:
            f.write(pareto_markdown(rows))
    return result


# -------------------------------------------------------------- pareto

def annotate_pareto(rows: list) -> list:
    """Add ``quality_per_byte`` (accuracy points per MiB kept — higher
    is better) and the ``pareto`` flag (no other point has both lower
    perplexity and fewer bytes)."""
    for r in rows:
        if r.get("acc") is not None and r.get("bytes_after"):
            r["quality_per_byte"] = r["acc"] / (r["bytes_after"] / 2 ** 20)
        else:
            r["quality_per_byte"] = None
    scored = [r for r in rows
              if r.get("ppl") is not None and r.get("bytes_after")]
    for r in rows:
        if r.get("ppl") is None or not r.get("bytes_after"):
            r["pareto"] = False
            continue
        r["pareto"] = not any(
            o is not r
            and o["ppl"] <= r["ppl"] and o["bytes_after"] <= r["bytes_after"]
            and (o["ppl"] < r["ppl"] or o["bytes_after"] < r["bytes_after"])
            for o in scored)
    return rows


def _fmt(row: dict, col: str) -> str:
    v = row.get(col)
    if v is None:
        return ""
    if col in ("ppl", "acc"):
        return f"{v:.4f}"
    if col in ("prune_seconds", "point_seconds"):
        return f"{v:.4f}"
    if col in ("flop_savings", "quality_per_byte"):
        return f"{v:.6g}"
    if col == "pareto":
        return "1" if v else "0"
    return str(v)


def pareto_csv(rows: list) -> str:
    lines = [",".join(CSV_COLUMNS)]
    lines += [",".join(_fmt(r, c) for c in CSV_COLUMNS) for r in rows]
    return "\n".join(lines) + "\n"


def pareto_markdown(rows: list) -> str:
    head = "| " + " | ".join(CSV_COLUMNS) + " |"
    sep = "|" + "|".join("---" for _ in CSV_COLUMNS) + "|"
    body = ["| " + " | ".join(_fmt(r, c) or "-" for c in CSV_COLUMNS) + " |"
            for r in rows]
    return "\n".join([head, sep] + body) + "\n"
