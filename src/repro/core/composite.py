"""Composite projection pruning (the paper's headline contribution).

Unstructured pruning at the POD targets keeps quality; structured pruning
*of the weight groups the masks have already hollowed out* shrinks the
model. Paper order (PC step 9): mask first, then remove the
lowest-magnitude heads/channels — the mask decides which groups die.
"""
from __future__ import annotations

from typing import Optional


from repro.core import structured as S
from repro.core import unstructured as U
from repro.core.registry import register_category
from repro.models.specs import ModelConfig


def prune_composite(params, cfg: ModelConfig, targets: dict,
                    selector: str = "wanda",
                    anorms: Optional[dict] = None,
                    hessians: Optional[dict] = None,
                    structured_share: float = 0.5,
                    align_heads: int = 1, align_channels: int = 1,
                    per_output: bool = True,
                    block: int = 16):
    """Returns (new_params, new_cfg, info).

    targets: per-projection POD targets (mean == p). structured_share: the
    fraction of each target realised as physical group removal; the mask
    realises the full target first, so groups removed second are mostly
    zeros already and total removed parameters land near p.
    """
    params, masks = U.prune_unstructured(
        params, cfg, targets, selector=selector, anorms=anorms,
        hessians=hessians, per_output=per_output, block=block)
    fractions = S.structured_fractions(targets, cfg, share=structured_share)
    new_params, new_cfg = S.prune_structured(
        params, cfg, fractions, align_heads=align_heads,
        align_channels=align_channels)
    info = {
        "unstructured_sparsity": U.achieved_sparsity(masks),
        "structured_fractions": fractions,
    }
    return new_params, new_cfg, info


@register_category("composite")
def _category_composite(params, cfg, targets, artifact, recipe):
    """The paper's headline mode: mask at full target, then physically
    remove the hollowed-out groups at ``structured_share``."""
    return prune_composite(
        params, cfg, targets, selector=recipe.selector,
        anorms=artifact.anorms, hessians=artifact.hessians,
        structured_share=recipe.structured_share,
        align_heads=recipe.align_heads,
        align_channels=recipe.align_channels,
        per_output=recipe.per_output, block=recipe.block)
