"""LoRA adapters for post-pruning recovery fine-tuning (E4, Fig. 10).

Adapters attach to every 2-D+ projection; only A/B train. ``merge`` folds
the adapter into the base weights for deployment (the paper's 84 MB adapter
merged at runtime).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.tree import tree_get, tree_set
from repro.core.registry import projections
from repro.models.specs import ModelConfig


def init_lora(key: jax.Array, params, cfg: ModelConfig, rank: int = 8,
              alpha: float = 16.0) -> dict:
    """{(layer, name): {'a': (in, r), 'b': (r, out)}} per projection."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    adapters = {}
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        shape = w.shape
        if proj.expert_axis is not None:
            e, cin, cout = shape
            a_shape, b_shape = (e, cin, rank), (e, rank, cout)
        else:
            cin = 1
            for ax in proj.in_axes:
                cin *= shape[ax]
            cout = int(jnp.prod(jnp.asarray(shape))) // cin
            a_shape, b_shape = (cin, rank), (rank, cout)
        key, sub = jax.random.split(key)
        adapters[proj.key] = {
            "a": (jax.random.normal(sub, a_shape) / math.sqrt(cin)
                  ).astype(jnp.float32),
            "b": jnp.zeros(b_shape, jnp.float32),
        }
    return adapters


def merge_lora(params, cfg: ModelConfig, adapters: dict,
               alpha: float = 16.0, rank: int = 8,
               masks: Optional[dict] = None):
    """base W + (alpha/r)·A@B, reshaped to W's layout. If masks given, the
    delta is masked so unstructured sparsity is preserved."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    scale = alpha / rank
    for proj in projections(cfg):
        if proj.key not in adapters:
            continue
        ab = adapters[proj.key]
        w = tree_get(params, proj.path)
        if proj.expert_axis is not None:
            delta = jnp.einsum("eir,ero->eio", ab["a"], ab["b"]) * scale
        else:
            delta = (ab["a"] @ ab["b"] * scale).reshape(w.shape)
        if masks is not None and proj.key in masks:
            delta = jnp.where(masks[proj.key], delta, 0.0)
        params = tree_set(params, proj.path, (w + delta.astype(w.dtype)))
    return params
