"""SparseGPT (Frantar & Alistarh) one-shot OBS pruning, in JAX.

Given a projection W (in -> out) and the input Gram matrix H = X^T X from
calibration, prune to a target sparsity while updating surviving weights to
minimise reconstruction error ||XW - XW'||_2. Column-blocked exactly like
the reference implementation: per block, scores w²/diag(U)² with a
block-global threshold, then the OBS rank-1 update sweeps the error into
later columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.registry import Projection

BLOCK = 128
PERCDAMP = 0.01


def _hinv_chol(H: jax.Array) -> jax.Array:
    """Upper Cholesky factor U of H^{-1} (so H^{-1} = U^T U)."""
    C = H.shape[0]
    diag = jnp.diag(H)
    dead = diag <= 0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    damp = PERCDAMP * jnp.mean(jnp.diag(H))
    H = H + damp * jnp.eye(C, dtype=H.dtype)
    Hinv = jnp.linalg.inv(H)
    # force symmetry before Cholesky (numerical)
    Hinv = 0.5 * (Hinv + Hinv.T)
    return jax.scipy.linalg.cholesky(Hinv, lower=False)


def _prune_block(Wb: jax.Array, Ub: jax.Array, target: float):
    """Prune one column block. Wb: (R, bs), Ub: (bs, bs) upper. Returns
    (Wb_new, Eb, maskb)."""
    R, bs = Wb.shape
    d = jnp.diag(Ub)                                        # (bs,)
    scores = jnp.square(Wb) / jnp.square(d)[None, :]
    k = int(target * R * bs)
    if k <= 0:
        maskb = jnp.ones((R, bs), bool)
    else:
        flat = jnp.sort(scores.reshape(-1))
        thresh = flat[min(k, R * bs - 1)]
        maskb = scores > thresh

    def body(j, carry):
        W, E = carry
        w_j = W[:, j]
        q = w_j * maskb[:, j]
        err = (w_j - q) / Ub[j, j]
        row = Ub[j]                                          # (bs,)
        upd = err[:, None] * row[None, :]
        later = (jnp.arange(bs) > j)[None, :]
        W = W - jnp.where(later, upd, 0.0)
        W = W.at[:, j].set(q)
        E = E.at[:, j].set(err)
        return W, E

    Wb, Eb = jax.lax.fori_loop(0, bs, body,
                               (Wb, jnp.zeros((R, bs), Wb.dtype)))
    return Wb, Eb, maskb


def sparsegpt_dense(W_io: jax.Array, H: jax.Array, target: float):
    """W_io: (in, out); H: (in, in). Returns (new_W_io, mask_io)."""
    Cin = W_io.shape[0]
    W = W_io.astype(jnp.float32).T                           # (R=out, Cin)
    diag = jnp.diag(H)
    W = W * (diag > 0)[None, :]                              # zero dead inputs
    U = _hinv_chol(H.astype(jnp.float32))
    masks = []
    for j1 in range(0, Cin, BLOCK):
        j2 = min(j1 + BLOCK, Cin)
        Wb, Eb, mb = _prune_block(W[:, j1:j2], U[j1:j2, j1:j2], target)
        W = W.at[:, j1:j2].set(Wb)
        if j2 < Cin:
            W = W.at[:, j2:].add(-Eb @ U[j1:j2, j2:])
        masks.append(mb)
    mask = jnp.concatenate(masks, axis=1)                    # (R, Cin)
    W = W * mask
    return W.T, mask.T


def sparsegpt_prune(w: jax.Array, H: jax.Array, target: float,
                    proj: Projection):
    """Shape-polymorphic wrapper: handles (in,out), (in,H,D), (H,D,out),
    and expert-batched (E,in,out) layouts."""
    orig_shape = w.shape
    if proj.expert_axis is not None:
        fn = functools.partial(_sparsegpt_2d, target=target)
        new_w, mask = jax.vmap(fn)(w, H)
        return new_w.reshape(orig_shape), mask.reshape(orig_shape)
    if proj.in_axes == (0,):
        w2 = w.reshape(orig_shape[0], -1)
        new_w, mask = sparsegpt_dense(w2, H, target)
    elif proj.in_axes == (0, 1):
        cin = orig_shape[0] * orig_shape[1]
        w2 = w.reshape(cin, -1)
        new_w, mask = sparsegpt_dense(w2, H, target)
    else:
        raise ValueError(proj.in_axes)
    return (new_w.reshape(orig_shape).astype(w.dtype),
            mask.reshape(orig_shape))


def _sparsegpt_2d(w, H, target):
    return sparsegpt_dense(w, H, target)
