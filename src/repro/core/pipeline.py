"""MosaicPipeline: declarative, staged execution of the paper's Fig. 6
flow — RC profiling -> projection planning -> category execution ->
post-pruning block packing -> report.

Stages are named entries in ``repro.core.registry.STAGES`` operating on
a shared :class:`PipelineContext`; a :class:`~repro.core.recipe.
PruneRecipe` picks the ordered subset to run (default all five). The
result is a :class:`~repro.core.artifact.PrunedArtifact` that serializes
to disk and rehydrates at serve time with zero re-derivation.

    recipe = PruneRecipe(arch="llama3-8b", p=0.6, category="composite")
    artifact = MosaicPipeline(recipe).run(params, cfg)
    artifact.save("results/pruned")      # launch/serve.py --artifact ...
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.common.tree import param_bytes, param_count
from repro.core import evaluate as _EV        # noqa: F401 (registers stage)
from repro.core import planner as PL
from repro.core import prune_controller as PC
from repro.core.artifact import PrunedArtifact
from repro.core.rank_controller import RankArtifact, profile_model
from repro.core.recipe import PruneRecipe
from repro.core.registry import CATEGORIES, STAGES, register_stage
from repro.models.specs import ModelConfig


@dataclasses.dataclass
class PipelineContext:
    """Mutable state threaded through the stages."""
    recipe: PruneRecipe
    params: Any
    cfg: ModelConfig
    calibration: Optional[list] = None
    platform: Optional[PC.Platform] = None
    rank_artifact: Optional[RankArtifact] = None
    eval_batches: Optional[dict] = None   # held-out set for 'evaluate'
    quality: Optional[dict] = None        # {'ppl': ..., 'acc': ...}
    targets: Optional[dict] = None
    category: Optional[str] = None
    info: dict = dataclasses.field(default_factory=dict)
    packed: dict = dataclasses.field(default_factory=dict)
    pack_report: Optional[dict] = None
    dense_params: int = 0
    dense_bytes: int = 0
    timings: dict = dataclasses.field(default_factory=dict)
    report: dict = dataclasses.field(default_factory=dict)


# ------------------------------------------------------------- stages

@register_stage("rank")
def stage_rank(ctx: PipelineContext) -> None:
    """RC profiling; reuses a caller-provided RankArtifact if present
    (one profile serves every p and category — the paper's E5 win)."""
    if ctx.rank_artifact is not None:
        return
    calib = ctx.calibration
    if calib is None:
        from repro.data.pipeline import SyntheticCorpus
        c = ctx.recipe.calibration
        corpus = SyntheticCorpus(ctx.cfg.vocab, seed=c.seed)
        calib = corpus.calibration_batches(c.n_samples, c.batch_size,
                                           c.seq_len)
    ctx.rank_artifact = profile_model(
        ctx.params, ctx.cfg, calib,
        want_hessians=ctx.recipe.selector == "sparsegpt")


@register_stage("plan")
def stage_plan(ctx: PipelineContext) -> None:
    """Projection Planner: global rank + p -> per-projection targets."""
    if ctx.rank_artifact is None:
        raise RuntimeError("'plan' needs a rank artifact: run the 'rank' "
                           "stage first or pass rank_artifact= to run()")
    ctx.targets = PL.plan_from_recipe(ctx.rank_artifact.rank, ctx.recipe,
                                      weights=ctx.rank_artifact.weights)


@register_stage("prune")
def stage_prune(ctx: PipelineContext) -> None:
    """Category execution via the plug-in registry (PC steps 9-10)."""
    if ctx.targets is None:
        raise RuntimeError("'prune' needs targets: run the 'plan' stage")
    cat = PC.resolve_category(ctx.recipe, ctx.dense_bytes, ctx.platform)
    fn = CATEGORIES.get(cat)
    ctx.params, ctx.cfg, info = fn(ctx.params, ctx.cfg, ctx.targets,
                                   ctx.rank_artifact, ctx.recipe)
    ctx.category = cat
    ctx.info.update(info)


@register_stage("pack")
def stage_pack(ctx: PipelineContext) -> None:
    """Post-Pruning Optimizer: block plans for the serving kernel —
    per-projection plans for dense weights, per-expert plan stacks for
    MoE expert weights (the report's ``skipped`` list only ever carries
    ``reason: "non-tileable"`` now; experts are planned, not skipped).
    ``recipe.group_experts`` marks the expert stacks for the grouped
    one-launch kernel (the default serving path) vs the per-expert
    launch loop, ``recipe.ragged_moe`` for the ragged routed-tokens-only
    dispatch at decode sizes; the flags ride inside each plan through
    the artifact bundle, so rehydrated engines pick the same path with
    no repacking.

    ``recipe.quant="int8"`` additionally compacts each plan's kept
    tiles into int8 + pow2-scale storage and *replaces* the quantized
    projections' params with their fake-quant round-trip — the dense
    forward, the evaluate stage, and the dequantized reference path
    then all see exactly the weights the int8 kernels compute with."""
    from repro.serve.sparse import apply_fake_quant, pack_model_with_report
    ctx.packed, ctx.pack_report = pack_model_with_report(
        ctx.params, ctx.cfg, block=ctx.recipe.block,
        group_experts=ctx.recipe.group_experts,
        ragged_moe=ctx.recipe.ragged_moe,
        quant=ctx.recipe.quant)
    if ctx.recipe.quant == "int8":
        ctx.params = apply_fake_quant(ctx.params, ctx.cfg, ctx.packed)


@register_stage("report")
def stage_report(ctx: PipelineContext) -> None:
    """Provenance + timing summary (the CI-tracked production-time row).

    With a quantized pack, ``bytes_after`` is real storage: the dense
    bytes of every quantized projection are swapped for its int8 tile +
    scale + plan bytes from the pack report."""
    r = ctx.recipe
    ra = ctx.rank_artifact
    bytes_after = param_bytes(ctx.params)
    qb = (ctx.pack_report or {}).get("quant_bytes")
    if qb:
        bytes_after += qb["total_bytes"] - qb["dense_bytes"]
    ctx.report.update({
        "arch": r.arch,
        "p": r.p,
        "category": ctx.category,
        "granularity": r.granularity,
        "selector": r.selector,
        "quant": r.quant,
        "params_before": ctx.dense_params,
        "bytes_before": ctx.dense_bytes,
        "params_after": param_count(ctx.params),
        "bytes_after": bytes_after,
        "profile_seconds": ra.profile_seconds if ra else None,
        "calibration_tokens": ra.n_tokens if ra else None,
        "prune_seconds": (ctx.timings.get("plan", 0.0)
                          + ctx.timings.get("prune", 0.0)),
        "pack": ctx.pack_report,
        "info": _jsonable(ctx.info),
        "stage_seconds": {k: round(v, 6) for k, v in ctx.timings.items()},
        "pipeline_seconds": round(sum(ctx.timings.values()), 6),
        "recipe": r.to_dict(),
    })
    if ctx.quality:                       # 'evaluate' ran before 'report'
        ctx.report.update(ctx.quality)


def _jsonable(obj):
    """Best-effort JSON projection (tuple keys -> 'a:b' strings)."""
    if isinstance(obj, dict):
        return {(":".join(str(p) for p in k) if isinstance(k, tuple)
                 else str(k)): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    return obj


# ------------------------------------------------------------ pipeline

class MosaicPipeline:
    """Executes a :class:`PruneRecipe`'s stages in order."""

    def __init__(self, recipe: PruneRecipe,
                 stages: Optional[tuple] = None):
        self.recipe = recipe
        self.stage_names = tuple(stages if stages is not None
                                 else recipe.stages)
        for name in self.stage_names:      # fail fast on unknown stages
            STAGES.get(name)

    def run(self, params, cfg: ModelConfig, *,
            calibration: Optional[list] = None,
            rank_artifact: Optional[RankArtifact] = None,
            eval_batches: Optional[dict] = None,
            platform: Optional[PC.Platform] = None) -> PrunedArtifact:
        cfg = cfg if not cfg.scan_layers else cfg.unrolled()
        ctx = PipelineContext(
            recipe=self.recipe, params=params, cfg=cfg,
            calibration=calibration, rank_artifact=rank_artifact,
            eval_batches=eval_batches,
            platform=platform, dense_params=param_count(params),
            dense_bytes=param_bytes(params))
        for name in self.stage_names:
            t0 = time.perf_counter()
            STAGES.get(name)(ctx)
            ctx.timings[name] = time.perf_counter() - t0
        return PrunedArtifact(params=ctx.params, cfg=ctx.cfg,
                              recipe=self.recipe, targets=ctx.targets or {},
                              packed=ctx.packed, report=ctx.report,
                              info=ctx.info)
