"""Declarative pruning recipe: one JSON document drives the whole
Mosaic pipeline (Fig. 6) — RC profiling, projection planning, category
execution, block-plan packing, and reporting.

A :class:`PruneRecipe` is a frozen dataclass with an exact JSON
round-trip (``to_json`` / ``from_json``); the same file works for
``launch/prune.py --recipe`` and ``launch/serve.py --recipe``, and is
embedded verbatim into every saved :class:`~repro.core.artifact.
PrunedArtifact` as provenance.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

GRANULARITIES = ("global", "layer", "projection")
DEFAULT_STAGES = ("rank", "plan", "prune", "pack", "report")
QUANT_MODES = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class CalibrationSpec:
    """How to draw the RC calibration set (paper: 128 x 2048 tokens)."""
    n_samples: int = 32
    batch_size: int = 8
    seq_len: int = 64
    seed: int = 0

    def __post_init__(self):
        if self.n_samples <= 0 or self.batch_size <= 0 or self.seq_len <= 0:
            raise ValueError(f"calibration sizes must be positive: {self}")


@dataclasses.dataclass(frozen=True)
class PruneRecipe:
    """Everything the Mosaic pipeline needs, declaratively.

    ``category=None`` defers to platform-based selection (PC step 9);
    ``platform`` names a preset in ``prune_controller.PLATFORMS``.
    ``block`` is the block-sparse kernel tile the ``pack`` stage plans
    for; ``group_experts`` marks MoE expert plan stacks for the grouped
    (one-launch-for-all-experts) kernel instead of the per-expert launch
    loop; ``ragged_moe`` additionally marks them for the ragged
    (routed-tokens-only) dispatch at decode batch sizes. ``quant``
    ("none" | "int8") makes the pack stage compact each plan's *kept*
    tiles into int8 storage with per-tile pow2 scales — the sparse ×
    quantized serving path. ``stages`` is the ordered subset of the
    stage registry to run.
    """
    arch: str
    p: float
    category: Optional[str] = None
    granularity: str = "projection"
    selector: str = "wanda"
    spread: float = 0.25
    within_spread: float = 0.1
    structured_share: float = 0.5
    align_heads: int = 1
    align_channels: int = 1
    per_output: bool = True
    platform: Optional[str] = None
    block: int = 128
    group_experts: bool = True
    ragged_moe: bool = False
    quant: str = "none"
    calibration: CalibrationSpec = CalibrationSpec()
    stages: tuple = DEFAULT_STAGES

    def __post_init__(self):
        if not 0.0 <= self.p < 1.0:
            raise ValueError(f"pruning target p={self.p} outside [0, 1)")
        if self.granularity not in GRANULARITIES:
            raise ValueError(f"unknown granularity {self.granularity!r}; "
                             f"choices: {GRANULARITIES}")
        if self.quant not in QUANT_MODES:
            raise ValueError(f"unknown quant {self.quant!r}; "
                             f"choices: {QUANT_MODES}")
        if not 0.0 <= self.structured_share <= 1.0:
            raise ValueError(
                f"structured_share={self.structured_share} outside [0, 1]")
        if self.block <= 0:
            raise ValueError(f"block={self.block} must be positive")
        # selector/category names are validated against the plug-in
        # registries at execution time (registration is import-driven)
        object.__setattr__(self, "stages", tuple(self.stages))

    # ------------------------------------------------------------- codec

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["stages"] = list(self.stages)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PruneRecipe":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown recipe fields: {sorted(unknown)}")
        calib = d.get("calibration")
        if isinstance(calib, dict):
            d["calibration"] = CalibrationSpec(**calib)
        if "stages" in d:
            d["stages"] = tuple(d["stages"])
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "PruneRecipe":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "PruneRecipe":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    def replace(self, **kw) -> "PruneRecipe":
        return dataclasses.replace(self, **kw)
