"""Projection Outlier Distribution (POD) — Eqs. 5-6 and Algorithm 1.

For each projection m in layer n:
    ω_{n,m}  = ||A_n||_2 · |θ_{n,m}|                      (Eq. 5)
    outlier  = ω^i > α · mean(ω_{n,m})                    (Eq. 6)
    R_{n,m}  = 100 · #outliers / #params                  (Alg. 1 l.15)
The normalised R_LLM is the *global rank*: projection importance comparable
across the whole model. Higher rank (more outliers) => more important =>
pruned less.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_get
from repro.core.registry import Projection, projections
from repro.models.specs import ModelConfig

DEFAULT_ALPHA = 5.0


def weight_metric(w: jax.Array, anorm: jax.Array, proj: Projection) -> jax.Array:
    """Eq. 5 elementwise: |W| scaled by the input-channel activation norm."""
    w = jnp.abs(w.astype(jnp.float32))
    if proj.expert_axis is not None:
        # w: (E, in, out), anorm: (E, in)
        return w * anorm[:, :, None]
    if proj.in_axes == (0,):
        shape = [w.shape[0]] + [1] * (w.ndim - 1)
        return w * anorm.reshape(shape)
    if proj.in_axes == (0, 1):
        # o-projection (H, D, d), anorm (H, D)
        return w * anorm[:, :, None]
    raise ValueError(f"unsupported in_axes {proj.in_axes}")


def outlier_ratio(metric: jax.Array, alpha: float = DEFAULT_ALPHA) -> jax.Array:
    """Eq. 6 within one projection: fraction of ω above α·mean(ω), in %."""
    flat = metric.reshape(-1)
    thresh = alpha * jnp.mean(flat)
    return 100.0 * jnp.mean((flat > thresh).astype(jnp.float32))


def global_rank(params, cfg: ModelConfig, anorms: dict,
                alpha: float = DEFAULT_ALPHA,
                per_expert: bool = False) -> dict:
    """Algorithm 1: the Mosaic Parameter Ranking Controller core.

    Returns {(layer, proj_name): normalised rank}. Normalisation maps the
    outlier ratios to mean 1.0 so the planner composes with any p.
    """
    raw: dict = {}
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        anorm = anorms[(proj.layer, proj.tap)]
        metric = weight_metric(w, anorm, proj)
        if proj.expert_axis is not None and not per_expert:
            raw[proj.key] = float(outlier_ratio(metric.reshape(-1), alpha))
        elif proj.expert_axis is not None:
            ratios = jax.vmap(lambda m: outlier_ratio(m, alpha))(metric)
            raw[proj.key] = np.asarray(ratios)
        else:
            raw[proj.key] = float(outlier_ratio(metric, alpha))
    return normalize_rank(raw)


def normalize_rank(raw: dict) -> dict:
    """Rank Post-Processor (Fig. 5 step 6): scale ranks to mean 1.0."""
    vals = []
    for v in raw.values():
        vals.extend(np.atleast_1d(v).tolist())
    mean = float(np.mean(vals)) if vals else 1.0
    if mean <= 0:
        return {k: np.ones_like(np.asarray(v, dtype=np.float64)) if np.ndim(v)
                else 1.0 for k, v in raw.items()}
    return {k: (np.asarray(v, np.float64) / mean if np.ndim(v) else v / mean)
            for k, v in raw.items()}


def layer_rank(rank: dict) -> dict:
    """Collapse a projection rank to per-layer ranks (the OWL/LOD baseline)."""
    by_layer: dict[int, list] = {}
    for (layer, _), v in rank.items():
        by_layer.setdefault(layer, []).extend(np.atleast_1d(v).tolist())
    return {layer: float(np.mean(v)) for layer, v in by_layer.items()}
