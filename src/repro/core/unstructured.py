"""Unstructured projection pruning: per-projection masks at POD targets.

Selectors:
  magnitude — |W|
  wanda     — |W| · ||A||_2  (Eq. 5 metric; the paper's ranking metric)
  sparsegpt — OBS scores w²/diag(H⁻¹)² with weight update (repro.core.sparsegpt)

Masked weights are exactly zero; mask counts use floor(target·numel) so the
achieved sparsity is exact and idempotent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.tree import tree_get, tree_set
from repro.core.pod import weight_metric
from repro.core.registry import (Projection, projections, register_category,
                                 register_selector, SELECTORS)
from repro.models.specs import ModelConfig


def mask_from_scores(scores: jax.Array, target: float) -> jax.Array:
    """Keep the highest-scoring (1-target) fraction. Exact count semantics."""
    flat = scores.reshape(-1).astype(jnp.float32)
    k_prune = int(target * flat.size)
    if k_prune <= 0:
        return jnp.ones(scores.shape, bool)
    if k_prune >= flat.size:
        return jnp.zeros(scores.shape, bool)
    order = jnp.argsort(flat)                      # ascending
    mask_flat = jnp.ones((flat.size,), bool).at[order[:k_prune]].set(False)
    return mask_flat.reshape(scores.shape)


def block_mask_from_metric(scores: jax.Array, target: float,
                           block: int = 16) -> jax.Array:
    """TPU-native semi-structured mask: prune whole (block x block) tiles
    with the lowest aggregate metric (DESIGN.md §3.1 — the analogue of
    2:4 sparsity; every pruned tile is skipped by the block-sparse
    Pallas kernel)."""
    s2 = scores.reshape(scores.shape[0], -1) if scores.ndim != 2 else scores
    K, N = s2.shape
    Kb, Nb = K // block, N // block
    if Kb == 0 or Nb == 0:
        return mask_from_scores(scores, target)
    trimmed = s2[:Kb * block, :Nb * block]
    tiles = trimmed.reshape(Kb, block, Nb, block).sum((1, 3))
    tile_mask = mask_from_scores(tiles, target)
    full = jnp.repeat(jnp.repeat(tile_mask, block, 0), block, 1)
    out = jnp.ones((K, N), bool).at[:Kb * block, :Nb * block].set(full)
    return out.reshape(scores.shape)


def per_output_mask(scores: jax.Array, target: float,
                    in_axes: tuple) -> jax.Array:
    """Wanda-style: prune the lowest fraction *within each output neuron*."""
    # Move input axes to the front, flatten: (In, Out)
    ndim = scores.ndim
    in_ax = tuple(a % ndim for a in in_axes)
    perm = list(in_ax) + [a for a in range(ndim) if a not in in_ax]
    s = jnp.transpose(scores, perm)
    in_dim = 1
    for a in in_ax:
        in_dim *= scores.shape[a]
    s2 = s.reshape(in_dim, -1)
    k_prune = int(target * in_dim)
    if k_prune <= 0:
        m2 = jnp.ones_like(s2, bool)
    else:
        order = jnp.argsort(s2, axis=0)
        rank = jnp.argsort(order, axis=0)          # rank of each entry
        m2 = rank >= k_prune
    m = m2.reshape(s.shape)
    inv = [0] * ndim
    for i, a in enumerate(perm):
        inv[a] = i
    return jnp.transpose(m, inv)


def score_projection(w: jax.Array, proj: Projection, selector: str,
                     anorms: Optional[dict]) -> jax.Array:
    if selector == "magnitude":
        return jnp.abs(w.astype(jnp.float32))
    if selector == "wanda":
        if anorms is None:
            raise ValueError("wanda selector needs activation norms")
        return weight_metric(w, anorms[(proj.layer, proj.tap)], proj)
    raise ValueError(f"unknown selector {selector!r}")


@dataclasses.dataclass
class SelectorContext:
    """Side inputs a selector may need (from the RC artifact / recipe)."""
    anorms: Optional[dict] = None
    hessians: Optional[dict] = None
    per_output: bool = False
    block: int = 16              # mask tile for block selectors


def _mask_and_zero(w, scores, target, proj, ctx: SelectorContext):
    if ctx.per_output:
        mask = per_output_mask(scores, target, proj.in_axes)
    else:
        mask = mask_from_scores(scores, target)
    return jnp.where(mask, w, jnp.zeros_like(w)), mask


@register_selector("magnitude")
def _sel_magnitude(w, proj, target, ctx):
    return _mask_and_zero(w, jnp.abs(w.astype(jnp.float32)), target, proj, ctx)


@register_selector("wanda")
def _sel_wanda(w, proj, target, ctx):
    return _mask_and_zero(w, score_projection(w, proj, "wanda", ctx.anorms),
                          target, proj, ctx)


@register_selector("wanda_block")
def _sel_wanda_block(w, proj, target, ctx):
    scores = score_projection(w, proj, "wanda", ctx.anorms)
    # mask tile == pack tile, so every pruned tile is a skipped tile
    if proj.expert_axis is not None:
        # per-expert tiles: the pack stage plans each expert's 2-D fold
        # independently, so the mask must tile each expert independently
        # too (a fold across the leading E axis would misalign)
        mask = jnp.stack([
            block_mask_from_metric(scores[e], target, block=ctx.block)
            for e in range(scores.shape[0])])
    else:
        mask = block_mask_from_metric(scores, target, block=ctx.block)
    return jnp.where(mask, w, jnp.zeros_like(w)), mask


@register_selector("sparsegpt")
def _sel_sparsegpt(w, proj, target, ctx):
    from repro.core.sparsegpt import sparsegpt_prune
    if ctx.hessians is None:
        raise ValueError("sparsegpt selector needs calibration hessians")
    return sparsegpt_prune(w, ctx.hessians[(proj.layer, proj.tap)],
                           target, proj)


def prune_unstructured(params, cfg: ModelConfig, targets: dict,
                       selector: str = "wanda",
                       anorms: Optional[dict] = None,
                       hessians: Optional[dict] = None,
                       per_output: bool = False,
                       block: int = 16):
    """Apply per-projection masks. Returns (new_params, masks).

    targets: {(layer, name): fraction}. ``selector`` names an entry in
    ``registry.SELECTORS``; 'sparsegpt' additionally updates surviving
    weights (OBS reconstruction). ``block`` is the tile size for block
    selectors — keep it equal to the serving kernel's pack block.
    """
    sel = SELECTORS.get(selector)
    ctx = SelectorContext(anorms=anorms, hessians=hessians,
                          per_output=per_output, block=block)
    masks: dict = {}
    for proj in projections(cfg):
        w = tree_get(params, proj.path)
        new_w, mask = sel(w, proj, targets.get(proj.key, 0.0), ctx)
        params = tree_set(params, proj.path, new_w.astype(w.dtype))
        masks[proj.key] = mask
    return params, masks


@register_category("unstructured")
def _category_unstructured(params, cfg, targets, artifact, recipe):
    """Mask-only pruning: quality-first, shapes unchanged."""
    params, masks = prune_unstructured(
        params, cfg, targets, selector=recipe.selector,
        anorms=artifact.anorms, hessians=artifact.hessians,
        per_output=recipe.per_output, block=recipe.block)
    return params, cfg, {"unstructured_sparsity": achieved_sparsity(masks)}


def achieved_sparsity(masks: dict) -> float:
    total = sum(int(m.size) for m in masks.values())
    zeros = sum(int(m.size) - int(jnp.sum(m)) for m in masks.values())
    return zeros / max(total, 1)
