"""Unstructured projection pruning: per-projection masks at POD targets.

Selectors:
  magnitude — |W|
  wanda     — |W| · ||A||_2  (Eq. 5 metric; the paper's ranking metric)
  sparsegpt — OBS scores w²/diag(H⁻¹)² with weight update (repro.core.sparsegpt)

Masked weights are exactly zero; mask counts use floor(target·numel) so the
achieved sparsity is exact and idempotent.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.tree import tree_get, tree_set
from repro.core.pod import weight_metric
from repro.core.registry import Projection, projections
from repro.models.specs import ModelConfig


def mask_from_scores(scores: jax.Array, target: float) -> jax.Array:
    """Keep the highest-scoring (1-target) fraction. Exact count semantics."""
    flat = scores.reshape(-1).astype(jnp.float32)
    k_prune = int(target * flat.size)
    if k_prune <= 0:
        return jnp.ones(scores.shape, bool)
    if k_prune >= flat.size:
        return jnp.zeros(scores.shape, bool)
    order = jnp.argsort(flat)                      # ascending
    mask_flat = jnp.ones((flat.size,), bool).at[order[:k_prune]].set(False)
    return mask_flat.reshape(scores.shape)


def block_mask_from_metric(scores: jax.Array, target: float,
                           block: int = 16) -> jax.Array:
    """TPU-native semi-structured mask: prune whole (block x block) tiles
    with the lowest aggregate metric (DESIGN.md §3.1 — the analogue of
    2:4 sparsity; every pruned tile is skipped by the block-sparse
    Pallas kernel)."""
    s2 = scores.reshape(scores.shape[0], -1) if scores.ndim != 2 else scores
    K, N = s2.shape
    Kb, Nb = K // block, N // block
    if Kb == 0 or Nb == 0:
        return mask_from_scores(scores, target)
    trimmed = s2[:Kb * block, :Nb * block]
    tiles = trimmed.reshape(Kb, block, Nb, block).sum((1, 3))
    tile_mask = mask_from_scores(tiles, target)
    full = jnp.repeat(jnp.repeat(tile_mask, block, 0), block, 1)
    out = jnp.ones((K, N), bool).at[:Kb * block, :Nb * block].set(full)
    return out.reshape(scores.shape)


def per_output_mask(scores: jax.Array, target: float,
                    in_axes: tuple) -> jax.Array:
    """Wanda-style: prune the lowest fraction *within each output neuron*."""
    # Move input axes to the front, flatten: (In, Out)
    ndim = scores.ndim
    in_ax = tuple(a % ndim for a in in_axes)
    perm = list(in_ax) + [a for a in range(ndim) if a not in in_ax]
    s = jnp.transpose(scores, perm)
    in_dim = 1
    for a in in_ax:
        in_dim *= scores.shape[a]
    s2 = s.reshape(in_dim, -1)
    k_prune = int(target * in_dim)
    if k_prune <= 0:
        m2 = jnp.ones_like(s2, bool)
    else:
        order = jnp.argsort(s2, axis=0)
        rank = jnp.argsort(order, axis=0)          # rank of each entry
        m2 = rank >= k_prune
    m = m2.reshape(s.shape)
    inv = [0] * ndim
    for i, a in enumerate(perm):
        inv[a] = i
    return jnp.transpose(m, inv)


def score_projection(w: jax.Array, proj: Projection, selector: str,
                     anorms: Optional[dict]) -> jax.Array:
    if selector == "magnitude":
        return jnp.abs(w.astype(jnp.float32))
    if selector == "wanda":
        if anorms is None:
            raise ValueError("wanda selector needs activation norms")
        return weight_metric(w, anorms[(proj.layer, proj.tap)], proj)
    raise ValueError(f"unknown selector {selector!r}")


def prune_unstructured(params, cfg: ModelConfig, targets: dict,
                       selector: str = "wanda",
                       anorms: Optional[dict] = None,
                       hessians: Optional[dict] = None,
                       per_output: bool = False):
    """Apply per-projection masks. Returns (new_params, masks).

    targets: {(layer, name): fraction}. selector='sparsegpt' additionally
    updates surviving weights (OBS reconstruction).
    """
    masks: dict = {}
    for proj in projections(cfg):
        t = targets.get(proj.key, 0.0)
        w = tree_get(params, proj.path)
        if selector == "sparsegpt":
            from repro.core.sparsegpt import sparsegpt_prune
            H = hessians[(proj.layer, proj.tap)]
            new_w, mask = sparsegpt_prune(w, H, t, proj)
        elif selector == "wanda_block":
            scores = score_projection(w, proj, "wanda", anorms)
            mask = block_mask_from_metric(scores, t)
            new_w = jnp.where(mask, w, jnp.zeros_like(w))
        else:
            scores = score_projection(w, proj, selector, anorms)
            if per_output:
                mask = per_output_mask(scores, t, proj.in_axes)
            else:
                mask = mask_from_scores(scores, t)
            new_w = jnp.where(mask, w, jnp.zeros_like(w))
        params = tree_set(params, proj.path, new_w.astype(w.dtype))
        masks[proj.key] = mask
    return params, masks


def achieved_sparsity(masks: dict) -> float:
    total = sum(int(m.size) for m in masks.values())
    zeros = sum(int(m.size) - int(jnp.sum(m)) for m in masks.values())
    return zeros / max(total, 1)
