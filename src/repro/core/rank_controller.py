"""Mosaic Parameter Ranking Controller (Fig. 5 / Algorithm 1).

Profiles the LLM once over a calibration set and emits the reusable global
rank R_LLM. One profile serves every pruning level p and every pruning
category (the paper's key overhead win, E5) — and, via
:meth:`RankArtifact.save` / :meth:`RankArtifact.load`, every future
*process*: a profile is a first-class on-disk artifact that
``launch/sweep.py`` fans across whole recipe grids.

Profiling is single-pass: when SparseGPT Hessians are wanted the
calibration forward collects both the POD ssq stats and the Gram
matrices in one sweep (tap mode ``both``); a profile taken without
Hessians can have them attached later with :func:`ensure_hessians`.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Iterable, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import calibrate as C
from repro.core import pod
from repro.core.registry import projections
from repro.common.tree import tree_get
from repro.models.specs import ModelConfig

PROFILE_FILE = "profile.json"
PROFILE_ARRAYS = "profile.npz"


@dataclasses.dataclass
class RankArtifact:
    """Output of the RC: everything the PC needs."""
    rank: dict                  # {(layer, name): normalised rank}
    anorms: dict                # {(layer, tap): ||A||_2 per channel}
    weights: dict               # {(layer, name): param count}
    n_tokens: int
    profile_seconds: float
    hessians: Optional[dict] = None     # only when sparsegpt requested

    # ----------------------------------------------------------- save/load
    # Layout: <dir>/profile.npz (rank/anorms/hessians arrays, keys
    # "<group>/<layer>:<name>") + profile.json (weights, token count,
    # timing). Writes are atomic via the CheckpointManager sidecar API.

    def save(self, directory: str) -> str:
        mgr = CheckpointManager(directory, keep=1)
        arrays = {}
        for (layer, name), v in self.rank.items():
            arrays[f"rank/{layer}:{name}"] = np.asarray(v)
        for (layer, tap), v in self.anorms.items():
            arrays[f"anorms/{layer}:{tap}"] = np.asarray(v)
        if self.hessians is not None:
            for (layer, tap), v in self.hessians.items():
                arrays[f"hessians/{layer}:{tap}"] = np.asarray(v)
        mgr.save_arrays(PROFILE_ARRAYS, arrays)
        mgr.save_json(PROFILE_FILE, {
            "kind": "rank_artifact",
            "n_tokens": int(self.n_tokens),
            "profile_seconds": float(self.profile_seconds),
            "has_hessians": self.hessians is not None,
            "weights": [[layer, name, int(v)] for (layer, name), v
                        in sorted(self.weights.items())],
        })
        return directory

    @staticmethod
    def is_artifact(directory: str) -> bool:
        return (os.path.isdir(directory)
                and os.path.exists(os.path.join(directory, PROFILE_FILE))
                and os.path.exists(os.path.join(directory, PROFILE_ARRAYS)))

    @classmethod
    def load(cls, directory: str) -> "RankArtifact":
        if not cls.is_artifact(directory):
            raise FileNotFoundError(
                f"{directory!r} is not a RankArtifact bundle "
                f"(missing {PROFILE_FILE}/{PROFILE_ARRAYS})")
        mgr = CheckpointManager(directory, keep=1)
        meta = mgr.load_json(PROFILE_FILE)
        rank, anorms, hessians = {}, {}, {}
        for key, arr in mgr.load_arrays(PROFILE_ARRAYS).items():
            group, rest = key.split("/", 1)
            layer, name = rest.split(":", 1)
            k = (int(layer), name)
            if group == "rank":
                # scalar ranks round-trip as 0-d arrays -> back to float
                rank[k] = float(arr) if arr.ndim == 0 else arr
            elif group == "anorms":
                anorms[k] = jnp.asarray(arr)
            elif group == "hessians":
                hessians[k] = jnp.asarray(arr)
        weights = {(int(layer), name): int(v)
                   for layer, name, v in meta["weights"]}
        return cls(rank=rank, anorms=anorms, weights=weights,
                   n_tokens=int(meta["n_tokens"]),
                   profile_seconds=float(meta["profile_seconds"]),
                   hessians=hessians if meta["has_hessians"] else None)


def profile_model(params, cfg: ModelConfig,
                  calibration_batches: Iterable,
                  alpha: float = pod.DEFAULT_ALPHA,
                  want_hessians: bool = False) -> RankArtifact:
    """RC profiling (the pipeline's ``rank`` stage): one calibration pass
    over the model emits the reusable global rank R_LLM.

    Single-pass even with ``want_hessians``: the forward collects the ssq
    stats and the SparseGPT Grams together (tap mode ``both``), so the
    calibration iterable is consumed exactly once and never materialised
    a second time.
    """
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    t0 = time.perf_counter()
    mode = "both" if want_hessians else "ssq"
    stats, n_tokens = C.calibrate(params, cfg, calibration_batches,
                                  mode=mode)
    hessians = None
    if want_hessians:
        stats, hessians = C.split_stats(stats)
    anorms = C.activation_norms(stats)
    rank = pod.global_rank(params, cfg, anorms, alpha=alpha)
    weights = {p.key: int(np.prod(tree_get(params, p.path).shape))
               for p in projections(cfg)}
    return RankArtifact(rank=rank, anorms=anorms, weights=weights,
                        n_tokens=n_tokens,
                        profile_seconds=time.perf_counter() - t0,
                        hessians=hessians)


def ensure_hessians(artifact: RankArtifact, params, cfg: ModelConfig,
                    calibration_batches: Iterable) -> RankArtifact:
    """Lazily attach SparseGPT Hessians to a Hessian-free profile.

    The sweep path profiles once without Hessians and only pays the Gram
    accumulation when a ``sparsegpt`` recipe point actually appears. The
    input artifact is not mutated; a no-op when Hessians are present.
    """
    if artifact.hessians is not None:
        return artifact
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    t0 = time.perf_counter()
    hessians, _ = C.calibrate(params, cfg, calibration_batches,
                              mode="hessian")
    return dataclasses.replace(
        artifact, hessians=hessians,
        profile_seconds=artifact.profile_seconds
        + (time.perf_counter() - t0))


def run_ranking_controller(params, cfg: ModelConfig,
                           calibration_batches: Iterable,
                           alpha: float = pod.DEFAULT_ALPHA,
                           want_hessians: bool = False) -> RankArtifact:
    """Deprecated shim — use :func:`profile_model`, or run the ``rank``
    stage of :class:`repro.core.pipeline.MosaicPipeline`."""
    return profile_model(params, cfg, calibration_batches, alpha=alpha,
                         want_hessians=want_hessians)
