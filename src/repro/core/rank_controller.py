"""Mosaic Parameter Ranking Controller (Fig. 5 / Algorithm 1).

Profiles the LLM once over a calibration set and emits the reusable global
rank R_LLM. One profile serves every pruning level p and every pruning
category (the paper's key overhead win, E5).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Optional

import numpy as np

from repro.core import calibrate as C
from repro.core import pod
from repro.core.registry import projections
from repro.common.tree import tree_get
from repro.models.specs import ModelConfig


@dataclasses.dataclass
class RankArtifact:
    """Output of the RC: everything the PC needs."""
    rank: dict                  # {(layer, name): normalised rank}
    anorms: dict                # {(layer, tap): ||A||_2 per channel}
    weights: dict               # {(layer, name): param count}
    n_tokens: int
    profile_seconds: float
    hessians: Optional[dict] = None     # only when sparsegpt requested


def profile_model(params, cfg: ModelConfig,
                  calibration_batches: Iterable,
                  alpha: float = pod.DEFAULT_ALPHA,
                  want_hessians: bool = False) -> RankArtifact:
    """RC profiling (the pipeline's ``rank`` stage): one calibration pass
    over the model emits the reusable global rank R_LLM."""
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    t0 = time.perf_counter()
    batches = list(calibration_batches)
    stats, n_tokens = C.calibrate(params, cfg, batches, mode="ssq")
    anorms = C.activation_norms(stats)
    rank = pod.global_rank(params, cfg, anorms, alpha=alpha)
    weights = {p.key: int(np.prod(tree_get(params, p.path).shape))
               for p in projections(cfg)}
    hessians = None
    if want_hessians:
        hessians, _ = C.calibrate(params, cfg, batches, mode="hessian")
    return RankArtifact(rank=rank, anorms=anorms, weights=weights,
                        n_tokens=n_tokens,
                        profile_seconds=time.perf_counter() - t0,
                        hessians=hessians)


def run_ranking_controller(params, cfg: ModelConfig,
                           calibration_batches: Iterable,
                           alpha: float = pod.DEFAULT_ALPHA,
                           want_hessians: bool = False) -> RankArtifact:
    """Deprecated shim — use :func:`profile_model`, or run the ``rank``
    stage of :class:`repro.core.pipeline.MosaicPipeline`."""
    return profile_model(params, cfg, calibration_batches, alpha=alpha,
                         want_hessians=want_hessians)
