"""Pruned-model quality evaluation as a reusable core stage.

Perplexity and zero-shot next-token accuracy used to live in
``benchmarks/`` only; every sweep point needs them (Compresso-style:
quality is tracked per configuration, never assumed), so they are a core
module now and a registered pipeline stage (``evaluate``). A recipe that
includes ``evaluate`` in its stages gets ``ppl`` / ``acc`` in the
artifact report next to ``bytes_after`` / ``flop_savings`` — the raw
material of the sweep Pareto table.

The accuracy analogue of the paper's 7-dataset mean is three held-out
"tasks": top-1, top-5, and top-1 on a shifted-start-distribution split.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import register_stage
from repro.models import transformer as T
from repro.models.specs import ModelConfig


def perplexity(params, cfg: ModelConfig, batches: Iterable) -> float:
    """exp(mean cross-entropy) over (tokens, labels) batches."""
    tot = 0.0
    n = 0
    for tokens, labels in batches:
        logits, _, _ = T.forward(params, cfg, tokens,
                                 compute_dtype=jnp.float32)
        tot += float(T.cross_entropy(logits, labels, cfg.vocab))
        n += 1
    return math.exp(tot / max(n, 1))


def topk_accuracy(params, cfg: ModelConfig, batches: Iterable,
                  k: int = 5) -> tuple:
    """(top-1 %, top-k %) next-token accuracy (mean of batch means)."""
    top1 = topk = n = 0
    for tokens, labels in batches:
        logits, _, _ = T.forward(params, cfg, tokens,
                                 compute_dtype=jnp.float32)
        logits = logits[..., :cfg.vocab]
        pred = jnp.argmax(logits, -1)
        top1 += float((pred == labels).mean())
        topk += float((jax.lax.top_k(logits, k)[1]
                       == labels[..., None]).any(-1).mean())
        n += 1
    n = max(n, 1)
    return 100.0 * top1 / n, 100.0 * topk / n


def accuracy(params, cfg: ModelConfig, batches: Iterable,
             shifted_batches: Optional[Iterable] = None) -> float:
    """Mean zero-shot accuracy over the held-out "tasks": top-1, top-5,
    and (when provided) top-1 on the shifted-start split."""
    top1, top5 = topk_accuracy(params, cfg, batches)
    accs = [top1, top5]
    if shifted_batches is not None:
        accs.append(topk_accuracy(params, cfg, shifted_batches)[0])
    return float(np.mean(accs))


@dataclasses.dataclass(frozen=True)
class EvalSpec:
    """How to draw the synthetic held-out evaluation set. The start
    indices keep it disjoint from both training (batches 0..) and
    calibration (batches 10_000..)."""
    batch_size: int = 8
    seq_len: int = 64
    n_ppl: int = 6
    ppl_start: int = 5000
    n_acc: int = 4
    acc_start: int = 6000
    shift: int = 7              # start-distribution roll for the 3rd task
    seed: int = 0


def synthetic_eval_batches(vocab: int, spec: EvalSpec = EvalSpec()) -> dict:
    """Materialised held-out batches: {'ppl': [...], 'acc': [...],
    'shifted': [...]} of (tokens, labels) pairs."""
    from repro.data.pipeline import SyntheticCorpus
    c = SyntheticCorpus(vocab, seed=spec.seed)
    ppl = list(c.batches(spec.batch_size, spec.seq_len,
                         start=spec.ppl_start, n=spec.n_ppl))
    acc = list(c.batches(spec.batch_size, spec.seq_len,
                         start=spec.acc_start, n=spec.n_acc))
    c2 = SyntheticCorpus(vocab, seed=spec.seed)      # same chains
    c2.start_probs = np.roll(c2.start_probs, spec.shift)
    shifted = list(c2.batches(spec.batch_size, spec.seq_len,
                              start=spec.acc_start, n=spec.n_acc))
    return {"ppl": ppl, "acc": acc, "shifted": shifted}


def evaluate_quality(params, cfg: ModelConfig, batches: dict) -> dict:
    """The quality row every sweep point carries."""
    return {"ppl": perplexity(params, cfg, batches["ppl"]),
            "acc": accuracy(params, cfg, batches["acc"],
                            batches.get("shifted"))}


def default_eval_batches(cfg: ModelConfig, recipe) -> dict:
    """Small held-out set sized from the recipe's calibration spec —
    shared by the ``evaluate`` stage fallback and the sweep runner so an
    N-point sweep evaluates every point on identical data."""
    c = recipe.calibration
    spec = EvalSpec(batch_size=c.batch_size, seq_len=c.seq_len,
                    n_ppl=2, n_acc=2, seed=c.seed)
    return synthetic_eval_batches(cfg.vocab, spec)


@register_stage("evaluate")
def stage_evaluate(ctx) -> None:
    """Quality stage: ppl/acc of the (pruned) model in ctx, into the
    report. Works in any stage order — it updates ctx.report directly
    and stage_report also merges ctx.quality."""
    batches = ctx.eval_batches
    if batches is None:
        batches = default_eval_batches(ctx.cfg, ctx.recipe)
        ctx.eval_batches = batches
    ctx.quality = evaluate_quality(ctx.params, ctx.cfg, batches)
    ctx.report.update(ctx.quality)
