"""Projection Planner (Fig. 6 step 8): global rank + pruning target p ->
per-projection sparsity targets p_{n,m} with mean(p_{n,m}) == p (Eqs. 1-2).

Granularities:
  global     — every target = p                       (uniform baseline)
  layer      — one target per layer (OWL/LOD)         (quasi-non-uniform)
  projection — one target per projection (Mosaic POD) (fully non-uniform)
"""
from __future__ import annotations

from typing import Optional

import numpy as np

MAX_TARGET = 0.95


def plan_targets(rank: dict, p: float, spread: float = 0.25,
                 weights: Optional[dict] = None,
                 pmax: float = MAX_TARGET) -> dict:
    """Map normalised ranks (mean 1.0) to targets.

    t = p - s·(r - mean_r): more outliers => smaller target. s is chosen so
    the max deviation is `spread·p`, then targets are clipped and
    iteratively re-centred so the (optionally param-count-weighted) mean is
    exactly p — Eq. 1/2 hold by construction.
    """
    if not 0.0 <= p < 1.0:
        raise ValueError(f"pruning target p={p} outside [0, 1)")
    keys = sorted(rank.keys())
    r = np.array([float(np.mean(rank[k])) for k in keys], np.float64)
    if weights is not None:
        w = np.array([float(weights[k]) for k in keys], np.float64)
        w = w / w.sum()
    else:
        w = np.full(len(keys), 1.0 / max(len(keys), 1), np.float64)

    mean_r = float((r * w).sum())
    dev = r - mean_r
    max_dev = np.abs(dev).max()
    scale = (spread * p / max_dev) if max_dev > 1e-12 else 0.0
    t = p - scale * dev

    # re-centre under clipping so weighted mean == p exactly
    for _ in range(100):
        t = np.clip(t, 0.0, pmax)
        err = p - float((t * w).sum())
        if abs(err) < 1e-12:
            break
        # distribute the error over entries that still have headroom
        room = np.where(err > 0, pmax - t, t)
        movable = (room > 1e-12) & (w > 0)
        if not movable.any():
            break
        t = t + np.where(movable, err * w.sum() / (w * movable).sum(), 0.0)
    t = np.clip(t, 0.0, pmax)
    return {k: float(v) for k, v in zip(keys, t)}


def _layer_targets(rank: dict, p: float, spread: float,
                   weights: Optional[dict]) -> dict:
    from repro.core.pod import layer_rank, normalize_rank
    lr = normalize_rank(layer_rank(rank))
    lw = None
    if weights is not None:
        lw = {}
        for (layer, _), v in weights.items():
            lw[layer] = lw.get(layer, 0.0) + float(v)
    return plan_targets(lr, p, spread, lw)


def plan(rank: dict, p: float, granularity: str = "projection",
         spread: float = 0.25, within_spread: float = 0.1,
         weights: Optional[dict] = None) -> dict:
    """Targets at the requested granularity, keyed by (layer, proj_name).

    Projection granularity is *hierarchical*, per Eqs. 1-2: LOD-style layer
    targets p_n first (mean_n p_n == p, Eq. 1), then each layer's budget is
    split across its projections by their within-layer POD ranks
    (mean_m p_{n,m} == p_n, Eq. 2). This keeps the strong cross-layer
    signal and refines it within the layer.
    """
    if granularity == "global":
        return {k: p for k in rank}
    if granularity == "layer":
        lt = _layer_targets(rank, p, spread, weights)
        return {k: lt[k[0]] for k in rank}
    if granularity == "projection":
        import numpy as np
        lt = _layer_targets(rank, p, spread, weights)
        out = {}
        layers = sorted({k[0] for k in rank})
        for layer in layers:
            keys = [k for k in rank if k[0] == layer]
            sub = {k: float(np.mean(rank[k])) for k in keys}
            m = float(np.mean(list(sub.values())))
            sub = {k: (v / m if m > 0 else 1.0) for k, v in sub.items()}
            w = ({k: weights[k] for k in keys} if weights is not None
                 else None)
            out.update(plan_targets(sub, lt[layer], within_spread, w))
        return out
    raise ValueError(f"unknown granularity {granularity!r}")


def plan_from_recipe(rank: dict, recipe, weights: Optional[dict] = None) -> dict:
    """The pipeline's ``plan`` stage: targets from a declarative recipe."""
    return plan(rank, recipe.p, granularity=recipe.granularity,
                spread=recipe.spread, within_spread=recipe.within_spread,
                weights=weights)
