"""Calibration pass: per-projection input-activation statistics.

Runs the calibration set through the model once and accumulates, for every
projection input, either the per-channel l2 norm (Eq. 5's ``||A_n||_2``) or
the full Gram matrix ``X^T X`` (SparseGPT Hessian). This is the Mosaic RC's
"LLM Profiler" + "Activation Processor" (Fig. 5, steps 2-4).

Works under jit/pjit: the tap collector is drained within the trace, so the
same code calibrates a sharded 340B model on a pod (DESIGN.md §3.3).
"""
from __future__ import annotations

import functools
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.models import taps
from repro.models import transformer as T
from repro.models.specs import ModelConfig
from repro.core.registry import tap_sequence


def _forward_stats(params, cfg: ModelConfig, tokens, mode: str):
    """One batch -> {(layer, tap_name): stat}."""
    with taps.collecting(mode) as collected:
        T.forward(params, cfg, tokens, compute_dtype=jnp.float32)
    out = {}
    idx = 0
    for i, spec in enumerate(cfg.layers()):
        for name in tap_sequence(spec):
            got_name, stat = collected[idx]
            assert got_name == name, f"tap mismatch {got_name} != {name}"
            out[(i, name)] = stat
            idx += 1
    assert idx == len(collected), "unconsumed taps"
    return out


def calibrate(params, cfg: ModelConfig, batches: Iterable[jax.Array],
              mode: str = "ssq") -> dict:
    """Accumulate activation stats over calibration batches.

    mode='ssq'    -> {(layer, tap): per-channel sum of squares}
    mode='hessian'-> {(layer, tap): X^T X Gram matrix}
    mode='both'   -> {(layer, tap): (ssq, X^T X)} in ONE pass (see
                     :func:`split_stats`) — the profile-once path when
                     Hessians are also wanted.
    ``batches`` may be any iterable (including a generator): it is
    consumed exactly once.
    Returns (stats, n_tokens).
    """
    step = jax.jit(functools.partial(_forward_stats, cfg=cfg, mode=mode),
                   static_argnames=())
    total = None
    n_tokens = 0
    for tokens in batches:
        stats = step(params, tokens=tokens)
        n_tokens += tokens.size
        if total is None:
            total = stats
        else:
            total = jax.tree.map(jnp.add, total, stats)
    return total, n_tokens


def split_stats(both: dict) -> tuple:
    """mode='both' stats -> (ssq stats, hessian stats)."""
    return ({k: v[0] for k, v in both.items()},
            {k: v[1] for k, v in both.items()})


def activation_norms(stats: dict) -> dict:
    """ssq stats -> per-channel l2 norms (the ||A||_2 of Eq. 5)."""
    return {k: jnp.sqrt(v) for k, v in stats.items()}
