"""Structured projection pruning: physically remove attention heads,
feed-forward channels, MoE expert channels and SSD heads (Fig. 4).

TPU adaptation (DESIGN.md §3.2): kept group counts stay multiples of a
configurable alignment so pruned models remain shardable over the tensor-
parallel mesh axis and MXU-tile friendly. Scores are post-mask magnitudes
by default — heads hollowed out by unstructured pruning rank lowest, which
is exactly the paper's composite synergy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import register_category
from repro.models.specs import (AttentionSpec, LayerSpec, MambaSpec, MLPSpec,
                                ModelConfig, MoESpec)


def _aligned_keep(total: int, frac: float, align: int, min_keep: int) -> int:
    """Number of groups to keep: multiple of align, >= min_keep."""
    keep = total - int(round(frac * total))
    keep = max(min_keep, keep)
    if align > 1:
        keep = max(align, int(round(keep / align)) * align)
    return min(keep, total)


def _abs32(x) -> jax.Array:
    return jnp.abs(x.astype(jnp.float32))


# ---------------------------------------------------------------- attention

def prune_attention(block: dict, spec: AttentionSpec, frac: float,
                    align_heads: int) -> tuple[dict, AttentionSpec]:
    """Remove the lowest-magnitude q heads, equally per kv group."""
    attn = block["attn"]
    g = spec.n_q // spec.n_kv                      # q heads per kv group
    # score per q head: |q| + |o| mass
    hs = (_abs32(attn["q"]).sum((0, 2)) + _abs32(attn["o"]).sum((1, 2)))
    if "q_bias" in attn:
        hs = hs + _abs32(attn["q_bias"]).sum(-1)
    hs = np.asarray(hs).reshape(spec.n_kv, g)
    keep_per_group = _aligned_keep(
        g, frac, max(1, align_heads // spec.n_kv), 1)
    # keep total q heads multiple of align_heads when possible
    while (keep_per_group * spec.n_kv) % align_heads and keep_per_group < g:
        keep_per_group += 1
    kept = []
    for kv in range(spec.n_kv):
        order = np.argsort(-hs[kv])[:keep_per_group]
        kept.extend(sorted(kv * g + int(h) for h in order))
    kept = jnp.asarray(kept, jnp.int32)

    new_attn = dict(attn)
    new_attn["q"] = jnp.take(attn["q"], kept, axis=1)
    new_attn["o"] = jnp.take(attn["o"], kept, axis=0)
    if "q_bias" in attn:
        new_attn["q_bias"] = jnp.take(attn["q_bias"], kept, axis=0)
    new_block = dict(block)
    new_block["attn"] = new_attn
    new_spec = dataclasses.replace(spec, n_q=keep_per_group * spec.n_kv)
    return new_block, new_spec


# ---------------------------------------------------------------- mlp / moe

def prune_mlp(block: dict, spec: MLPSpec, frac: float,
              align_channels: int) -> tuple[dict, MLPSpec]:
    mlp = block["mlp"]
    cs = _abs32(mlp["up"]).sum(0) + _abs32(mlp["down"]).sum(1)
    if spec.gated:
        cs = cs + _abs32(mlp["gate"]).sum(0)
    keep = _aligned_keep(spec.d_ff, frac, align_channels, align_channels)
    kept = jnp.sort(jnp.argsort(-cs)[:keep])
    new_mlp = {k: v for k, v in mlp.items()}
    new_mlp["up"] = jnp.take(mlp["up"], kept, axis=1)
    new_mlp["down"] = jnp.take(mlp["down"], kept, axis=0)
    if spec.gated:
        new_mlp["gate"] = jnp.take(mlp["gate"], kept, axis=1)
    new_block = dict(block)
    new_block["mlp"] = new_mlp
    return new_block, dataclasses.replace(spec, d_ff=int(keep))


def prune_moe(block: dict, spec: MoESpec, frac: float,
              align_channels: int) -> tuple[dict, MoESpec]:
    moe = block["moe"]
    cs = _abs32(moe["up"]).sum(1) + _abs32(moe["down"]).sum(2)   # (E, ff)
    if spec.gated:
        cs = cs + _abs32(moe["gate"]).sum(1)
    keep = _aligned_keep(spec.d_ff, frac, align_channels,
                         min(align_channels, spec.d_ff))
    kept = jnp.sort(jnp.argsort(-cs, axis=1)[:, :keep], axis=1)  # (E, keep)
    take_out = jax.vmap(lambda w, idx: jnp.take(w, idx, axis=1))
    take_in = jax.vmap(lambda w, idx: jnp.take(w, idx, axis=0))
    new_moe = dict(moe)
    new_moe["up"] = take_out(moe["up"], kept)
    new_moe["down"] = take_in(moe["down"], kept)
    if spec.gated:
        new_moe["gate"] = take_out(moe["gate"], kept)
    new_block = dict(block)
    new_block["moe"] = new_moe
    return new_block, dataclasses.replace(spec, d_ff=int(keep))


def prune_experts(block: dict, spec: MoESpec, frac: float) -> tuple:
    """Beyond-paper extension: remove whole experts (the coarsest MoE
    group). Experts are scored by routed mass proxy (router column norm)
    x weight mass; at least top_k experts are kept and the router is
    re-shaped accordingly."""
    moe = block["moe"]
    E = spec.n_experts
    router_mass = _abs32(moe["router"]).sum(0)              # (E,)
    w_mass = _abs32(moe["up"]).sum((1, 2)) + _abs32(moe["down"]).sum((1, 2))
    if spec.gated:
        w_mass = w_mass + _abs32(moe["gate"]).sum((1, 2))
    score = np.asarray(router_mass * w_mass)
    keep = max(spec.top_k, E - int(round(frac * E)))
    kept = np.sort(np.argsort(-score)[:keep])
    kept_j = jnp.asarray(kept, jnp.int32)
    new_moe = dict(moe)
    new_moe["router"] = jnp.take(moe["router"], kept_j, axis=1)
    for nm in ("up", "down") + (("gate",) if spec.gated else ()):
        new_moe[nm] = jnp.take(moe[nm], kept_j, axis=0)
    new_block = dict(block)
    new_block["moe"] = new_moe
    return new_block, dataclasses.replace(spec, n_experts=int(keep))


# ---------------------------------------------------------------- mamba

def prune_mamba(block: dict, spec: MambaSpec, frac: float,
                align_heads: int) -> tuple[dict, MambaSpec]:
    """Remove whole SSD heads (head_dim-sized channel groups)."""
    m = block["mamba"]
    di, P, H = spec.d_inner, spec.head_dim, spec.n_heads
    GN = spec.n_groups * spec.d_state
    w_in = _abs32(m["in_proj"])
    z_mass = w_in[:, :di].sum(0).reshape(H, P).sum(1)
    x_mass = w_in[:, di:2 * di].sum(0).reshape(H, P).sum(1)
    out_mass = _abs32(m["out_proj"]).sum(1).reshape(H, P).sum(1)
    hs = np.asarray(z_mass + x_mass + out_mass)
    keep = _aligned_keep(H, frac, align_heads, align_heads)
    kept = np.sort(np.argsort(-hs)[:keep])

    ch = jnp.asarray(
        np.concatenate([np.arange(h * P, (h + 1) * P) for h in kept]),
        jnp.int32)                                        # kept inner channels
    kept_j = jnp.asarray(kept, jnp.int32)
    # in_proj column layout: [z(di), x(di), B(GN), C(GN), dt(H)]
    cols = jnp.concatenate([
        ch, di + ch,
        jnp.arange(2 * di, 2 * di + 2 * GN, dtype=jnp.int32),
        2 * di + 2 * GN + kept_j])
    new_m = dict(m)
    new_m["in_proj"] = jnp.take(m["in_proj"], cols, axis=1)
    # conv channel layout: [x(di), B(GN), C(GN)]
    conv_ch = jnp.concatenate([
        ch, jnp.arange(di, di + 2 * GN, dtype=jnp.int32)])
    new_m["conv_w"] = jnp.take(m["conv_w"], conv_ch, axis=0)
    new_m["conv_b"] = jnp.take(m["conv_b"], conv_ch, axis=0)
    for nm in ("A_log", "D", "dt_bias"):
        new_m[nm] = jnp.take(m[nm], kept_j, axis=0)
    new_m["norm_scale"] = jnp.take(m["norm_scale"], ch, axis=0)
    new_m["out_proj"] = jnp.take(m["out_proj"], ch, axis=0)
    new_block = dict(block)
    new_block["mamba"] = new_m
    return new_block, dataclasses.replace(spec, d_inner=int(keep) * P)


# ---------------------------------------------------------------- driver

def structured_fractions(targets: dict, cfg: ModelConfig,
                         share: float = 1.0) -> dict:
    """Per-(layer, unit) structured fraction from per-projection targets."""
    out: dict = {}
    for i, spec in enumerate(cfg.layers()):
        if isinstance(spec.mixer, AttentionSpec):
            vals = [targets.get((i, n), 0.0) for n in ("q", "k", "v", "o")]
            out[(i, "heads")] = share * float(np.mean(vals))
        else:
            vals = [targets.get((i, n), 0.0) for n in ("in_proj", "out_proj")]
            out[(i, "mamba")] = share * float(np.mean(vals))
        if spec.ffn is not None:
            names = ("gate", "up", "down")
            vals = [targets[(i, n)] for n in names if (i, n) in targets]
            out[(i, "ffn")] = share * float(np.mean(vals))
    return out


def prune_structured(params, cfg: ModelConfig, fractions: dict,
                     align_heads: int = 1, align_channels: int = 1,
                     expert_frac: float = 0.0):
    """Returns (new_params, new_cfg) with physically smaller projections."""
    assert not cfg.scan_layers, "structured pruning operates on unrolled models"
    new_blocks = []
    new_specs = []
    for i, spec in enumerate(cfg.layers()):
        block = params["blocks"][i]
        mixer = spec.mixer
        if isinstance(mixer, AttentionSpec):
            f = fractions.get((i, "heads"), 0.0)
            block, mixer = prune_attention(block, mixer, f, align_heads)
        else:
            f = fractions.get((i, "mamba"), 0.0)
            block, mixer = prune_mamba(block, mixer, f, align_heads)
        ffn = spec.ffn
        if isinstance(ffn, MoESpec):
            if expert_frac > 0.0:
                block, ffn = prune_experts(block, ffn, expert_frac)
            f = fractions.get((i, "ffn"), 0.0)
            block, ffn = prune_moe(block, ffn, f, align_channels)
        elif isinstance(ffn, MLPSpec):
            f = fractions.get((i, "ffn"), 0.0)
            block, ffn = prune_mlp(block, ffn, f, align_channels)
        new_blocks.append(block)
        new_specs.append(LayerSpec(mixer=mixer, ffn=ffn))
    new_params = dict(params)
    new_params["blocks"] = new_blocks
    new_cfg = cfg.replace(pattern=tuple(new_specs), n_periods=1,
                          scan_layers=False)
    return new_params, new_cfg


@register_category("structured")
def _category_structured(params, cfg, targets, artifact, recipe):
    """Physical-only pruning: maximum shrink for memory-bound targets."""
    fractions = structured_fractions(targets, cfg, share=1.0)
    params, new_cfg = prune_structured(
        params, cfg, fractions, align_heads=recipe.align_heads,
        align_channels=recipe.align_channels)
    return params, new_cfg, {"structured_fractions": fractions}
