"""Mosaic Parameter Pruning Controller (Fig. 6).

Category selection (PC step 9) and the deployment-platform presets live
here; category *execution* is pluggable — each category registers an
executor in ``repro.core.registry.CATEGORIES`` from its home module, and
the pipeline's ``prune`` stage dispatches by name.

``run_pruning_controller`` is a deprecation shim kept for existing
callers: it builds a :class:`~repro.core.recipe.PruneRecipe` and runs
the ``plan`` + ``prune`` stages of :class:`~repro.core.pipeline.
MosaicPipeline` against a precomputed :class:`~repro.core.
rank_controller.RankArtifact`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.common.tree import param_bytes
from repro.core import composite as COMP          # noqa: F401 (registers)
from repro.core import structured as S            # noqa: F401 (registers)
from repro.core import unstructured as U          # noqa: F401 (registers)
from repro.core.rank_controller import RankArtifact
from repro.core.recipe import PruneRecipe
from repro.models.specs import ModelConfig


@dataclasses.dataclass(frozen=True)
class Platform:
    """Deployment target descriptor (Table I analogue)."""
    name: str
    memory_bytes: int
    has_sparse_accel: bool = False   # TPU block-sparse kernel available
    tp_size: int = 1                 # tensor-parallel alignment requirement


PLATFORMS = {
    "cloud": Platform("cloud", 80 << 30, has_sparse_accel=True, tp_size=16),
    "edge": Platform("edge", 4 << 30),
    "mobile": Platform("mobile", 8 << 30),
}


def select_category(platform: Platform, dense_bytes: int, p: float,
                    structured_share: float = 0.5) -> str:
    """PC step 9: category by available memory (Section IV).

    Plenty of memory + sparsity acceleration -> unstructured (quality).
    Cannot fit even the composite model -> structured (max shrink).
    Otherwise -> composite. The composite size estimate uses the
    recipe's actual ``structured_share`` (the physically removed
    fraction of the target), not a hardcoded half.
    """
    if platform.has_sparse_accel and dense_bytes <= platform.memory_bytes:
        return "unstructured"
    composite_bytes = dense_bytes * (1.0 - structured_share * p)
    if composite_bytes <= platform.memory_bytes:
        return "composite"
    return "structured"


def resolve_category(recipe: PruneRecipe, dense_bytes: int,
                     platform: Optional[Platform] = None) -> str:
    """Recipe category, or platform-driven selection when deferred."""
    if recipe.category is not None:
        return recipe.category
    plat = platform
    if plat is None and recipe.platform is not None:
        if recipe.platform not in PLATFORMS:
            raise KeyError(f"unknown platform {recipe.platform!r}; "
                           f"presets: {sorted(PLATFORMS)}")
        plat = PLATFORMS[recipe.platform]
    if plat is None:
        return "composite"
    return select_category(plat, dense_bytes, recipe.p,
                           recipe.structured_share)


@dataclasses.dataclass
class PruneResult:
    params: dict
    cfg: ModelConfig
    category: str
    granularity: str
    targets: dict
    info: dict
    prune_seconds: float


def run_pruning_controller(params, cfg: ModelConfig, artifact: RankArtifact,
                           p: float,
                           platform: Optional[Platform] = None,
                           category: Optional[str] = None,
                           granularity: str = "projection",
                           selector: str = "wanda",
                           spread: float = 0.25,
                           within_spread: float = 0.1,
                           structured_share: float = 0.5,
                           align_heads: int = 1,
                           align_channels: int = 1,
                           per_output: bool = True) -> PruneResult:
    """Deprecated shim — build a :class:`PruneRecipe` and run
    :class:`~repro.core.pipeline.MosaicPipeline` instead."""
    from repro.core.pipeline import MosaicPipeline
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    if category is None and platform is not None:
        category = select_category(platform, param_bytes(params), p,
                                   structured_share)
    recipe = PruneRecipe(
        arch=cfg.name, p=p, category=category, granularity=granularity,
        selector=selector, spread=spread, within_spread=within_spread,
        structured_share=structured_share, align_heads=align_heads,
        align_channels=align_channels, per_output=per_output,
        block=16,                 # the historical wanda_block mask tile
        stages=("plan", "prune", "report"))
    art = MosaicPipeline(recipe).run(params, cfg, rank_artifact=artifact)
    return PruneResult(params=art.params, cfg=art.cfg,
                       category=art.report["category"],
                       granularity=granularity, targets=art.targets,
                       info=art.info,
                       prune_seconds=art.report["prune_seconds"])
