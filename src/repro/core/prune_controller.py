"""Mosaic Parameter Pruning Controller (Fig. 6).

Takes the RC's global rank + a user pruning target p, plans per-projection
sparsity targets, picks the pruning category for the target platform, and
produces a deployment-ready pruned model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.common.tree import param_bytes
from repro.core import composite as COMP
from repro.core import planner as PL
from repro.core import structured as S
from repro.core import unstructured as U
from repro.core.rank_controller import RankArtifact
from repro.models.specs import ModelConfig

CATEGORIES = ("unstructured", "structured", "composite")


@dataclasses.dataclass(frozen=True)
class Platform:
    """Deployment target descriptor (Table I analogue)."""
    name: str
    memory_bytes: int
    has_sparse_accel: bool = False   # TPU block-sparse kernel available
    tp_size: int = 1                 # tensor-parallel alignment requirement


def select_category(platform: Platform, dense_bytes: int, p: float) -> str:
    """PC step 9: category by available memory (Section IV).

    Plenty of memory + sparsity acceleration -> unstructured (quality).
    Cannot fit even the composite model -> structured (max shrink).
    Otherwise -> composite.
    """
    if platform.has_sparse_accel and dense_bytes <= platform.memory_bytes:
        return "unstructured"
    composite_bytes = dense_bytes * (1.0 - 0.5 * p)
    if composite_bytes <= platform.memory_bytes:
        return "composite"
    return "structured"


@dataclasses.dataclass
class PruneResult:
    params: dict
    cfg: ModelConfig
    category: str
    granularity: str
    targets: dict
    info: dict
    prune_seconds: float


def run_pruning_controller(params, cfg: ModelConfig, artifact: RankArtifact,
                           p: float,
                           platform: Optional[Platform] = None,
                           category: Optional[str] = None,
                           granularity: str = "projection",
                           selector: str = "wanda",
                           spread: float = 0.25,
                           within_spread: float = 0.1,
                           structured_share: float = 0.5,
                           align_heads: int = 1,
                           align_channels: int = 1,
                           per_output: bool = True) -> PruneResult:
    cfg = cfg if not cfg.scan_layers else cfg.unrolled()
    t0 = time.perf_counter()
    if category is None:
        if platform is None:
            category = "composite"
        else:
            category = select_category(platform, param_bytes(params), p)
    assert category in CATEGORIES, category

    targets = PL.plan(artifact.rank, p, granularity=granularity,
                      spread=spread, within_spread=within_spread,
                      weights=artifact.weights)
    info: dict = {}
    if category == "unstructured":
        params, masks = U.prune_unstructured(
            params, cfg, targets, selector=selector,
            anorms=artifact.anorms, hessians=artifact.hessians,
            per_output=per_output)
        info["unstructured_sparsity"] = U.achieved_sparsity(masks)
        new_cfg = cfg
    elif category == "structured":
        fractions = S.structured_fractions(targets, cfg, share=1.0)
        params, new_cfg = S.prune_structured(
            params, cfg, fractions, align_heads=align_heads,
            align_channels=align_channels)
        info["structured_fractions"] = fractions
    else:
        params, new_cfg, info = COMP.prune_composite(
            params, cfg, targets, selector=selector,
            anorms=artifact.anorms, hessians=artifact.hessians,
            structured_share=structured_share,
            align_heads=align_heads, align_channels=align_channels,
            per_output=per_output)
    return PruneResult(params=params, cfg=new_cfg, category=category,
                       granularity=granularity, targets=targets, info=info,
                       prune_seconds=time.perf_counter() - t0)
