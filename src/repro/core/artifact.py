"""PrunedArtifact: the serializable prune-time -> serve-time bundle.

Everything the serving stack needs to run a Mosaic-pruned model lands in
one directory: the pruned params (via :class:`CheckpointManager`), the
post-pruning :class:`ModelConfig`, the per-projection targets, the
block-sparse ``PackedProjection`` plans, the driving
:class:`PruneRecipe`, and a provenance/timing report. Serve startup
loads this bundle and rehydrates the saved plans — ``pack_model`` never
runs on the serve hot path.

Layout on disk::

    <dir>/
      step_00000000/arrays.npz  # pruned params (CheckpointManager)
      step_00000000/meta.json
      config.json               # post-pruning ModelConfig
      recipe.json               # the PruneRecipe that produced this
      targets.json              # [[layer, name, target], ...]
      plans.npz + plans.json    # block plans: PackedProjection entries
                                # plus leading-E PackedExpertProjection
                                # stacks for MoE expert weights
      report.json               # provenance, timings, pack coverage
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core.recipe import PruneRecipe
from repro.models.specs import ModelConfig, config_from_dict, config_to_dict

RECIPE_FILE = "recipe.json"
CONFIG_FILE = "config.json"
TARGETS_FILE = "targets.json"
REPORT_FILE = "report.json"
PLANS_FILE = "plans.npz"
PLANS_META_FILE = "plans.json"


@dataclasses.dataclass
class PrunedArtifact:
    params: Any
    cfg: ModelConfig
    recipe: PruneRecipe
    targets: dict                 # {(layer, name): sparsity target}
    packed: dict                  # {(layer, name): PackedProjection}
    report: dict                  # JSON-safe provenance + timings
    info: dict = dataclasses.field(default_factory=dict)  # raw (not saved)

    # --------------------------------------------------------------- save

    def save(self, directory: str) -> str:
        from repro.serve.sparse import plans_to_host
        mgr = CheckpointManager(directory, keep=1)
        mgr.save(0, self.params, blocking=True,
                 extra_meta={"kind": "pruned_artifact",
                             "arch": self.recipe.arch,
                             "category": self.report.get("category")})
        mgr.save_json(RECIPE_FILE, self.recipe.to_dict())
        mgr.save_json(CONFIG_FILE, config_to_dict(self.cfg))
        mgr.save_json(TARGETS_FILE,
                      [[layer, name, t] for (layer, name), t
                       in sorted(self.targets.items())])
        mgr.save_json(REPORT_FILE, self.report)
        arrays, meta = plans_to_host(self.packed)
        mgr.save_arrays(PLANS_FILE, arrays)
        mgr.save_json(PLANS_META_FILE, meta)
        return directory

    # --------------------------------------------------------------- load

    @staticmethod
    def is_artifact(directory: str) -> bool:
        return (os.path.isdir(directory)
                and os.path.exists(os.path.join(directory, RECIPE_FILE))
                and os.path.exists(os.path.join(directory, CONFIG_FILE)))

    @classmethod
    def load(cls, directory: str) -> "PrunedArtifact":
        from repro.models import transformer as T
        from repro.serve.sparse import plans_from_host
        if not cls.is_artifact(directory):
            raise FileNotFoundError(
                f"{directory!r} is not a PrunedArtifact bundle "
                f"(missing {RECIPE_FILE}/{CONFIG_FILE})")
        mgr = CheckpointManager(directory, keep=1)
        recipe = PruneRecipe.from_dict(mgr.load_json(RECIPE_FILE))
        cfg = config_from_dict(mgr.load_json(CONFIG_FILE))
        # restore params into the exact tree the pruned config implies
        like = jax.eval_shape(
            functools.partial(T.init_model, cfg=cfg), jax.random.PRNGKey(0))
        params = mgr.restore(like)
        targets = {(int(layer), name): float(t)
                   for layer, name, t in mgr.load_json(TARGETS_FILE)}
        packed = {}
        if mgr.has(PLANS_META_FILE):
            packed = plans_from_host(mgr.load_arrays(PLANS_FILE),
                                     mgr.load_json(PLANS_META_FILE))
        return cls(params=params, cfg=cfg, recipe=recipe, targets=targets,
                   packed=packed, report=mgr.load_json(REPORT_FILE))
