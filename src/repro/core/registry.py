"""Projection registry: enumerates every prunable projection of a model.

A *projection* (the paper's smallest LLM unit) is a 2-D+ weight with a
defined input-activation tap. The registry maps each to its param path,
tap name, and input-channel axes so POD / pruning are model-agnostic.

Operates on unrolled configs (``cfg.unrolled()``): ranking and pruning are
per-layer by definition (Eq. 2), so scanned stacks are unrolled first.

Also hosts the plug-in registries the declarative pipeline dispatches
through: mask *selectors* (magnitude / wanda / wanda_block / sparsegpt),
pruning *categories* (unstructured / structured / composite), and
pipeline *stages* (rank / plan / prune / pack / report). Implementations
self-register from their home modules, so adding a selector or category
is one decorated function — no if/elif chain to extend.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig, MoESpec)


class Registry:
    """Named plug-in table with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Callable] = {}

    def register(self, name: str) -> Callable:
        def deco(fn: Callable) -> Callable:
            if name in self._entries:
                raise ValueError(f"duplicate {self.kind} {name!r}")
            self._entries[name] = fn
            return fn
        return deco

    def get(self, name: str) -> Callable:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"unknown {self.kind} {name!r}; "
                           f"registered: {sorted(self._entries)}") from None

    def names(self) -> list:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries


# selector(w, proj, target, ctx) -> (new_w, mask); ctx: SelectorContext
SELECTORS = Registry("selector")
# category(params, cfg, targets, artifact, recipe) -> (params, cfg, info)
CATEGORIES = Registry("category")
# stage(ctx: PipelineContext) -> None (mutates ctx)
STAGES = Registry("stage")

register_selector = SELECTORS.register
register_category = CATEGORIES.register
register_stage = STAGES.register

# Canonical projection names per mixer/ffn kind, in paper order
# {Q, K, V, O, G, U, D}.
ATTN_PROJS = ("q", "k", "v", "o")
MLP_PROJS = ("gate", "up", "down")
MAMBA_PROJS = ("in_proj", "out_proj")


@dataclass(frozen=True)
class Projection:
    layer: int
    name: str                 # q|k|v|o|gate|up|down|in_proj|out_proj
    path: tuple               # param path, e.g. ('blocks', 3, 'attn', 'q')
    tap: str                  # activation tap supplying ||A||_2
    in_axes: tuple            # weight axes that are input channels
    expert_axis: Optional[int] = None   # leading expert axis for MoE weights

    @property
    def key(self) -> tuple:
        return (self.layer, self.name)


def layer_projections(i: int, spec: LayerSpec) -> list[Projection]:
    projs: list[Projection] = []
    base = ("blocks", i)
    if isinstance(spec.mixer, AttentionSpec):
        for nm in ("q", "k", "v"):
            projs.append(Projection(i, nm, base + ("attn", nm), "attn_qkv", (0,)))
        projs.append(Projection(i, "o", base + ("attn", "o"), "attn_o", (0, 1)))
    else:
        projs.append(Projection(i, "in_proj", base + ("mamba", "in_proj"),
                                "mamba_in", (0,)))
        projs.append(Projection(i, "out_proj", base + ("mamba", "out_proj"),
                                "mamba_out", (0,)))
    if isinstance(spec.ffn, MoESpec):
        names = ("gate", "up") if spec.ffn.gated else ("up",)
        for nm in names:
            projs.append(Projection(i, nm, base + ("moe", nm), "moe_in", (1,),
                                    expert_axis=0))
        projs.append(Projection(i, "down", base + ("moe", "down"), "moe_down",
                                (1,), expert_axis=0))
    elif isinstance(spec.ffn, MLPSpec):
        names = ("gate", "up") if spec.ffn.gated else ("up",)
        for nm in names:
            projs.append(Projection(i, nm, base + ("mlp", nm), "mlp_in", (0,)))
        projs.append(Projection(i, "down", base + ("mlp", "down"), "mlp_down", (0,)))
    return projs


def projections(cfg: ModelConfig) -> list[Projection]:
    assert not cfg.scan_layers, (
        "projection registry operates on unrolled configs; call cfg.unrolled()")
    out: list[Projection] = []
    for i, spec in enumerate(cfg.layers()):
        out.extend(layer_projections(i, spec))
    return out


def tap_sequence(spec: LayerSpec) -> list[str]:
    """The deterministic tap order emitted by one layer's forward."""
    seq = (["attn_qkv", "attn_o"] if isinstance(spec.mixer, AttentionSpec)
           else ["mamba_in", "mamba_out"])
    if isinstance(spec.ffn, MoESpec):
        seq += ["moe_in", "moe_down"]
    elif isinstance(spec.ffn, MLPSpec):
        seq += ["mlp_in", "mlp_down"]
    return seq
