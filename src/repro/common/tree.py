"""Pytree path utilities used across the framework.

Params are nested dicts (and lists for per-layer blocks). A *path* is a
tuple of keys, e.g. ``('blocks', 3, 'mixer', 'q')``.
"""
from __future__ import annotations

from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

Path = tuple
PyTree = Any


def tree_get(tree: PyTree, path: Path) -> Any:
    node = tree
    for key in path:
        node = node[key]
    return node


def tree_set(tree: PyTree, path: Path, value: Any) -> PyTree:
    """Functionally set ``value`` at ``path``, copying containers on the way."""
    if not path:
        return value
    key, rest = path[0], path[1:]
    if isinstance(tree, dict):
        new = dict(tree)
        new[key] = tree_set(tree[key], rest, value)
        return new
    if isinstance(tree, list):
        new_l = list(tree)
        new_l[key] = tree_set(tree[key], rest, value)
        return new_l
    if isinstance(tree, tuple):
        new_t = list(tree)
        new_t[key] = tree_set(tree[key], rest, value)
        return tuple(new_t)
    raise TypeError(f"Cannot set path {path!r} in {type(tree)}")


def tree_update(tree: PyTree, updates: dict[Path, Any]) -> PyTree:
    for path, value in updates.items():
        tree = tree_set(tree, path, value)
    return tree


def iter_paths(tree: PyTree, prefix: Path = ()) -> Iterator[tuple[Path, Any]]:
    """Yield (path, leaf) for every array leaf."""
    if isinstance(tree, dict):
        for key in sorted(tree):
            yield from iter_paths(tree[key], prefix + (key,))
    elif isinstance(tree, (list, tuple)):
        for i, sub in enumerate(tree):
            yield from iter_paths(sub, prefix + (i,))
    elif tree is None:
        return
    else:
        yield prefix, tree


def tree_map_with_path(fn: Callable[[Path, Any], Any], tree: PyTree,
                       prefix: Path = ()) -> PyTree:
    if isinstance(tree, dict):
        return {k: tree_map_with_path(fn, v, prefix + (k,)) for k, v in tree.items()}
    if isinstance(tree, list):
        return [tree_map_with_path(fn, v, prefix + (i,)) for i, v in enumerate(tree)]
    if isinstance(tree, tuple):
        return tuple(tree_map_with_path(fn, v, prefix + (i,)) for i, v in enumerate(tree))
    if tree is None:
        return None
    return fn(prefix, tree)


def param_count(tree: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree: PyTree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
