"""Version compatibility for the jax APIs the repo uses.

The distributed code targets the modern spelling (``jax.shard_map``,
``jax.lax.pvary``); on jax 0.4.x those live under ``jax.experimental``
or don't exist. Import from here instead of feature-detecting inline.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.4.38
    from jax.experimental.shard_map import shard_map  # noqa: F401

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:
    # Pre-VMA shard_map has no varying-axis tracking: every value is
    # already device-varying, so marking is the identity.
    def pvary(x, axis_names):  # noqa: ARG001
        return x
