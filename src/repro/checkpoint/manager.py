"""Checkpointing: async, atomic, retained, reshardable.

Layout: <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
atomically renamed — a partially-written checkpoint is never visible.
Restore fills a "like" tree (from jax.eval_shape) by path, optionally
device_put with new shardings — so a checkpoint taken on one mesh restores
onto any other (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.common.tree import iter_paths, tree_set


def _path_key(path) -> str:
    return "/".join(str(p) for p in path)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_seconds = 0.0

    # -------------------------------------------------------------- save

    def save(self, step: int, tree: Any, blocking: bool = True,
             extra_meta: Optional[dict] = None) -> None:
        """Snapshot to host then write. blocking=False -> background thread
        (async checkpointing: train continues while IO happens)."""
        host = {(_path_key(p)): np.asarray(jax.device_get(leaf))
                for p, leaf in iter_paths(tree)}
        meta = {"step": step, "time": time.time(), **(extra_meta or {})}
        self.wait()
        if blocking:
            self._write(step, host, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()

    def _write(self, step: int, host: dict, meta: dict) -> None:
        t0 = time.perf_counter()
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        self.save_seconds = time.perf_counter() - t0

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----------------------------------------------------------- restore

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Fill ``like``-structured tree from checkpoint. ``shardings``
        (same structure, or None) controls placement — pass shardings for
        a *different* mesh to reshard on restore."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        tree = like
        for p, leaf in iter_paths(like):
            arr = data[_path_key(p)]
            if shardings is not None:
                shard = shardings
                for k in p:
                    if isinstance(shard, dict) or isinstance(shard, (list, tuple)):
                        shard = shard[k]
                arr = jax.device_put(arr, shard)
            else:
                arr = jax.numpy.asarray(arr, dtype=leaf.dtype)
            tree = tree_set(tree, p, arr)
        return tree

    def meta(self, step: Optional[int] = None) -> dict:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self.directory, f"step_{step:08d}",
                               "meta.json")) as f:
            return json.load(f)

    # ------------------------------------------------- sidecar documents
    # Artifact bundles (PrunedArtifact) keep JSON documents and auxiliary
    # array files next to the weight checkpoint; writes are atomic
    # (tmp + rename) like the checkpoint itself.

    def save_json(self, name: str, obj: Any) -> None:
        path = os.path.join(self.directory, name)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=2)
        os.replace(tmp, path)

    def load_json(self, name: str) -> Any:
        with open(os.path.join(self.directory, name)) as f:
            return json.load(f)

    def save_arrays(self, name: str, arrays: dict) -> None:
        path = os.path.join(self.directory, name)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)

    def load_arrays(self, name: str) -> dict:
        with np.load(os.path.join(self.directory, name)) as data:
            return {k: data[k] for k in data.files}

    def has(self, name: str) -> bool:
        return os.path.exists(os.path.join(self.directory, name))
