"""Deterministic synthetic data pipeline.

A Zipfian order-1 Markov corpus: every token has a small successor set with
Zipf-distributed transition probabilities, so small models can genuinely
learn structure (needed for the paper's perplexity orderings, E1-E4).
Batches are a pure function of (seed, batch_index, shard) — restartable,
shard-aware, and bit-reproducible across hosts.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticCorpus:
    def __init__(self, vocab: int, seed: int = 0, branching: int = 20,
                 zipf_a: float = 1.2):
        self.vocab = vocab
        self.branching = branching
        rng = np.random.default_rng(seed)
        self.successors = rng.integers(0, vocab, size=(vocab, branching),
                                       dtype=np.int32)
        w = 1.0 / np.arange(1, branching + 1) ** zipf_a
        self.probs = (w / w.sum()).astype(np.float64)
        # Zipfian start distribution
        sw = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self.start_probs = sw / sw.sum()
        self.seed = seed

    def batch(self, index: int, batch_size: int, seq_len: int,
              shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """(batch_size, seq_len + 1) int32 tokens; deterministic."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + index) * 97 + shard * n_shards)
        out = np.empty((batch_size, seq_len + 1), np.int32)
        out[:, 0] = rng.choice(self.vocab, size=batch_size, p=self.start_probs)
        choices = rng.choice(self.branching, size=(batch_size, seq_len),
                             p=self.probs)
        for t in range(seq_len):
            out[:, t + 1] = self.successors[out[:, t], choices[:, t]]
        return out

    def batches(self, batch_size: int, seq_len: int, start: int = 0,
                n: Optional[int] = None) -> Iterator[tuple]:
        """Yields (tokens, labels) pairs."""
        i = start
        while n is None or i < start + n:
            full = self.batch(i, batch_size, seq_len)
            yield full[:, :-1], full[:, 1:]
            i += 1

    def calibration_batches(self, n_samples: int, batch_size: int,
                            seq_len: int, seed_offset: int = 10_000) -> list:
        """The paper's 128-sample x 2048-token calibration set analogue."""
        out = []
        for i in range(0, n_samples, batch_size):
            bs = min(batch_size, n_samples - i)
            out.append(self.batch(seed_offset + i, bs, seq_len)[:, :-1])
        return out


class Prefetcher:
    """Background-thread prefetch with bounded queue (overlaps host-side
    data generation with device compute)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
