"""Core transformer layers: norms, RoPE, GQA attention, MLP variants.

Pure-functional: every module is an ``init_*`` returning a params dict and
an ``apply``-style function. Compute happens in ``cfg`` compute dtype
(params cast at use), accumulation in fp32 where it matters.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.axes import hint, hint_heads, model_axis_size
from repro.models.specs import AttentionSpec, MLPSpec
from repro.models.taps import tap

# Sequences longer than this use the chunked (flash-style, exact-FLOP)
# attention path; shorter use one dense softmax.
DENSE_ATTN_MAX = 2048
Q_CHUNK = 1024


# ---------------------------------------------------------------- norms

def init_norm(kind: str, dim: int, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(params: dict, kind: str, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(dtype)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------- RoPE

def rope_embed(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, D), positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention

def init_attention(key: jax.Array, d_model: int, spec: AttentionSpec,
                   dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(spec.n_q * spec.head_dim)
    p = {
        "q": (jax.random.normal(kq, (d_model, spec.n_q, spec.head_dim)) * s_in).astype(dtype),
        "k": (jax.random.normal(kk, (d_model, spec.n_kv, spec.head_dim)) * s_in).astype(dtype),
        "v": (jax.random.normal(kv, (d_model, spec.n_kv, spec.head_dim)) * s_in).astype(dtype),
        "o": (jax.random.normal(ko, (spec.n_q, spec.head_dim, d_model)) * s_out).astype(dtype),
    }
    if spec.qkv_bias:
        p["q_bias"] = jnp.zeros((spec.n_q, spec.head_dim), dtype)
        p["k_bias"] = jnp.zeros((spec.n_kv, spec.head_dim), dtype)
        p["v_bias"] = jnp.zeros((spec.n_kv, spec.head_dim), dtype)
    return p


def _dense_attention(q, k, v, q_positions, kv_positions, causal: bool,
                     kv_valid: Optional[jax.Array] = None):
    """q: (B,S,nq,D); k,v: (B,T,nkv,D). Returns (B,S,nq,D)."""
    B, S, nq, D = q.shape
    T, nkv = k.shape[1], k.shape[2]
    group = nq // nkv
    qg = q.reshape(B, S, nkv, group, D)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((B, S, T), bool)
    if causal:
        mask &= kv_positions[:, None, :] <= q_positions[:, :, None]
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, nq, D)


def _chunked_causal_attention(q, k, v, positions):
    """Exact-FLOP causal attention for long sequences.

    Unrolled loop over query chunks; chunk i attends to the kv prefix
    [0, (i+1)*Q_CHUNK) only (static slice), so no masked-block FLOP waste.
    This is the jnp oracle path; the Pallas flash kernel is the TPU
    hot-path equivalent (repro/kernels/flash_attention).
    """
    B, S, nq, D = q.shape
    nkv = k.shape[2]
    group = nq // nkv
    scale = 1.0 / math.sqrt(D)
    qc = Q_CHUNK
    n_chunks = (S + qc - 1) // qc
    assert S % qc == 0, f"seq {S} must be a multiple of {qc} for chunked attn"
    outs = []
    for i in range(n_chunks):
        qs = q[:, i * qc:(i + 1) * qc].reshape(B, qc, nkv, group, D)
        kv_len = (i + 1) * qc
        ks = k[:, :kv_len]
        vs = v[:, :kv_len]
        logits = jnp.einsum("bskgd,btkd->bkgst", qs, ks,
                            preferred_element_type=jnp.float32) * scale
        qpos = positions[:, i * qc:(i + 1) * qc]
        kpos = positions[:, :kv_len]
        mask = kpos[:, None, :] <= qpos[:, :, None]
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        outs.append(jnp.einsum("bkgst,btkd->bskgd", probs, vs)
                    .reshape(B, qc, nq, D))
    return jnp.concatenate(outs, axis=1)


# ------------------------------------------------------------ paged KV

def _paged_rows(block_tables: jax.Array, pos: jax.Array,
                block_size: int) -> jax.Array:
    """Physical arena rows for logical positions ``pos`` (B, S) through
    per-sequence ``block_tables`` (B, max_blocks)."""
    logical = jnp.clip(pos // block_size, 0, block_tables.shape[1] - 1)
    phys = jnp.take_along_axis(block_tables, logical, axis=1)
    return phys * block_size + pos % block_size


def paged_write(cache_kv: jax.Array, new: jax.Array,
                block_tables: jax.Array, start: jax.Array,
                n_valid: Optional[jax.Array] = None) -> jax.Array:
    """Scatter ``new`` (B, S, n_kv, D) K/V rows into a paged arena
    ``cache_kv`` (n_blocks, block_size, n_kv, D) at logical positions
    ``start[b] + [0, S)`` of each sequence's ``block_tables`` row.

    Rows past ``n_valid[b]`` (right-padded prefill positions) are
    redirected into the arena's last block — the reserved scratch block
    the allocator never hands out — so padding never corrupts a live
    block. jit-safe: ``start``/``n_valid`` may be traced.
    """
    nb, bs = cache_kv.shape[0], cache_kv.shape[1]
    B, S = new.shape[0], new.shape[1]
    flat = cache_kv.reshape(nb * bs, *cache_kv.shape[2:])
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    rows = _paged_rows(block_tables, pos, bs)
    if n_valid is not None:
        ok = jnp.arange(S, dtype=jnp.int32)[None, :] < n_valid[:, None]
        rows = jnp.where(ok, rows, nb * bs - 1)     # scratch block
    flat = flat.at[rows.reshape(-1)].set(
        new.astype(flat.dtype).reshape(B * S, *new.shape[2:]))
    return flat.reshape(cache_kv.shape)


def paged_gather(cache_kv: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Gather each sequence's logical KV view (B, max_blocks*block_size,
    n_kv, D) from the paged arena via its block table."""
    nb, bs = cache_kv.shape[0], cache_kv.shape[1]
    flat = cache_kv.reshape(nb * bs, *cache_kv.shape[2:])
    B, M = block_tables.shape
    rows = (block_tables[:, :, None] * bs
            + jnp.arange(bs, dtype=jnp.int32)[None, None, :])
    return flat[rows.reshape(B, M * bs)]


def apply_attention(params: dict, spec: AttentionSpec, x: jax.Array,
                    positions: jax.Array, cache: Optional[dict] = None,
                    cache_index: Optional[jax.Array] = None,
                    block_tables: Optional[jax.Array] = None,
                    n_valid: Optional[jax.Array] = None,
                    paged_kernel: bool = False,
                    interpret: bool = True):
    """Returns (out, new_cache). cache: {'k','v': (B, S_max, n_kv, D)},
    or a paged arena {'k','v': (n_blocks, block_size, n_kv, D)} when
    ``block_tables`` (B, max_blocks) maps each sequence's logical blocks
    onto arena blocks; ``n_valid`` (B,) masks right-padded positions of
    a padded (chunked) prefill. ``paged_kernel`` selects the fused Pallas
    decode kernel (``interpret`` in its CPU interpret mode) on the paged
    S==1 path; prefill and the default decode path use the gather
    reference."""
    dtype = x.dtype
    tap("attn_qkv", x)
    q = jnp.einsum("bsd,dhe->bshe", x, params["q"].astype(dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, params["k"].astype(dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, params["v"].astype(dtype))
    if spec.qkv_bias:
        q = q + params["q_bias"].astype(dtype)
        k = k + params["k_bias"].astype(dtype)
        v = v + params["v_bias"].astype(dtype)
    if spec.rope:
        q = rope_embed(q, positions, spec.rope_theta)
        k = rope_embed(k, positions, spec.rope_theta)

    # TP-friendly head layout for train/prefill: GQA groups whose kv/group
    # dims cannot shard over the model axis force head_dim-sharded
    # contractions whose *backward* all-gathers score-sized tensors.
    # Expanding kv to full heads (and zero-padding heads to a TP multiple)
    # keeps every attention collective out of the graph; padded heads are
    # sliced off before the o-projection. Decode keeps the compact GQA
    # cache layout (memory-bound; no backward).
    n_q_orig = q.shape[2]
    pad_heads = 0
    group = spec.n_q // spec.n_kv
    tp = model_axis_size()
    # Expand for compute whenever there is a real sequence dim (train +
    # prefill): the cache always stores the compact GQA layout; decode
    # (S==1) stays compact (memory-bound, no backward).
    expand = (tp > 1 and (spec.n_kv % tp or spec.n_q % tp)
              and x.shape[1] > 1)

    def _expand(kk, vv):
        if group > 1 or spec.n_kv % tp:
            kk = jnp.repeat(kk, group, axis=2)
            vv = jnp.repeat(vv, group, axis=2)
        return kk, vv

    new_cache = None
    if cache is not None and block_tables is not None:
        # paged pool: scatter this step's K/V rows through the block
        # table, then attend over the gathered logical view. The view
        # width (max_blocks * block_size) matches the contiguous pool's
        # S_max, so masked softmax sums are bitwise-identical to the
        # contiguous path — garbage rows in unwritten blocks get exact
        # zero probability (fp32 exp(-1e30 - max) underflows to 0).
        ci = jnp.asarray(cache_index, jnp.int32)
        if ci.ndim == 0:
            ci = jnp.broadcast_to(ci, (x.shape[0],))
        ck = paged_write(cache["k"], k, block_tables, ci, n_valid)
        cv = paged_write(cache["v"], v, block_tables, ci, n_valid)
        new_cache = {"k": ck, "v": cv}
        if paged_kernel and x.shape[1] == 1:
            # fused decode: the Pallas kernel walks the block table in
            # scalar memory and gathers arena blocks in-kernel — the
            # logical view below is never materialized
            from repro.kernels.paged_attention.ops import (
                paged_attention_decode)
            nv1 = (n_valid if n_valid is not None
                   else jnp.full((x.shape[0],), 1, jnp.int32))
            out = paged_attention_decode(q, ck, cv, block_tables,
                                         ci + nv1, interpret=interpret)
            tap("attn_o", out, channel_axes=(-2, -1))
            y = jnp.einsum("bshe,hed->bsd", out, params["o"].astype(dtype))
            return hint(y, "batch", "seq", "embed"), new_cache
        kview = paged_gather(ck, block_tables)
        vview = paged_gather(cv, block_tables)
        T_kv = kview.shape[1]
        kv_pos = jnp.broadcast_to(
            jnp.arange(T_kv, dtype=jnp.int32)[None, :],
            (x.shape[0], T_kv))
        nv = (n_valid if n_valid is not None
              else jnp.full((x.shape[0],), x.shape[1], jnp.int32))
        valid = kv_pos < (ci + nv)[:, None]
        out = _dense_attention(q, kview, vview, positions, kv_pos,
                               causal=spec.causal, kv_valid=valid)
        tap("attn_o", out, channel_axes=(-2, -1))
        y = jnp.einsum("bshe,hed->bsd", out, params["o"].astype(dtype))
        return hint(y, "batch", "seq", "embed"), new_cache
    if cache is not None:
        # write current step(s) at cache_index, attend over full cache.
        # cache_index is a scalar (whole batch at one offset: train-style
        # prefill/decode) or a (B,) vector (slot pool: every sequence at
        # its own length, continuous batching).
        ci = jnp.asarray(cache_index, jnp.int32)
        if ci.ndim == 0:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, ci, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, ci, axis=1)
        else:
            def upd(c, u, i):
                return jax.lax.dynamic_update_slice_in_dim(c, u, i, axis=0)
            ck = jax.vmap(upd)(cache["k"], k, ci)
            cv = jax.vmap(upd)(cache["v"], v, ci)
        new_cache = {"k": ck, "v": cv}
        if expand:
            ck, cv = _expand(ck, cv)
            pad_heads = (-spec.n_q) % tp
            if pad_heads:
                padc = ((0, 0), (0, 0), (0, pad_heads), (0, 0))
                q, ck, cv = (jnp.pad(t, padc) for t in (q, ck, cv))
        q = hint_heads(q)
        ck = hint_heads(ck, kv=True)
        cv = hint_heads(cv, kv=True)
        if x.shape[1] > DENSE_ATTN_MAX and spec.causal:
            # long prefill: cache content == current tokens (index 0);
            # exact-FLOP chunked attention instead of a full SxT score
            # matrix over the cache
            kq = ck[:, :x.shape[1]]
            vq = cv[:, :x.shape[1]]
            out = _chunked_causal_attention(q, kq, vq, positions)
        else:
            S_max = ck.shape[1]
            kv_pos = jnp.arange(S_max, dtype=jnp.int32)[None, :]
            kv_pos = jnp.broadcast_to(kv_pos, (x.shape[0], S_max))
            valid = kv_pos < ((ci[:, None] if ci.ndim else ci) + x.shape[1])
            out = _dense_attention(q, ck, cv, positions, kv_pos,
                                   causal=spec.causal, kv_valid=valid)
    else:
        if expand:
            k, v = _expand(k, v)
            pad_heads = (-spec.n_q) % tp
            if pad_heads:
                padc = ((0, 0), (0, 0), (0, pad_heads), (0, 0))
                q, k, v = (jnp.pad(t, padc) for t in (q, k, v))
        q = hint_heads(q)
        k = hint_heads(k, kv=True)
        v = hint_heads(v, kv=True)
        if x.shape[1] > DENSE_ATTN_MAX and spec.causal:
            out = _chunked_causal_attention(q, k, v, positions)
        else:
            out = _dense_attention(q, k, v, positions, positions,
                                   causal=spec.causal)
    if pad_heads:
        out = out[:, :, :n_q_orig, :]
    tap("attn_o", out, channel_axes=(-2, -1))
    out = hint_heads(out)
    y = jnp.einsum("bshe,hed->bsd", out, params["o"].astype(dtype))
    return hint(y, "batch", "seq", "embed"), new_cache


def init_attention_cache(batch: int, s_max: int, spec: AttentionSpec,
                         dtype=jnp.bfloat16) -> dict:
    shape = (batch, s_max, spec.n_kv, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_attention_cache(n_blocks: int, block_size: int,
                               spec: AttentionSpec,
                               dtype=jnp.bfloat16) -> dict:
    """A paged KV arena: ``n_blocks`` fixed-size blocks shared by every
    sequence (the last block is the padding scratch block)."""
    shape = (n_blocks, block_size, spec.n_kv, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------- MLP

def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":  # squared ReLU (Nemotron / Primer)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


def init_mlp(key: jax.Array, d_model: int, spec: MLPSpec, dtype=jnp.float32) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(spec.d_ff)
    p = {
        "up": (jax.random.normal(ku, (d_model, spec.d_ff)) * s_in).astype(dtype),
        "down": (jax.random.normal(kd, (spec.d_ff, d_model)) * s_out).astype(dtype),
    }
    if spec.gated:
        p["gate"] = (jax.random.normal(kg, (d_model, spec.d_ff)) * s_in).astype(dtype)
    return p


def apply_mlp(params: dict, spec: MLPSpec, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    tap("mlp_in", x)
    up = hint(x @ params["up"].astype(dtype), "batch", "seq", "ffn")
    if spec.gated:
        gate = activation(spec.act,
                          hint(x @ params["gate"].astype(dtype),
                               "batch", "seq", "ffn"))
        h = gate * up
    else:
        h = activation(spec.act, up)
    tap("mlp_down", h)
    return hint(h @ params["down"].astype(dtype), "batch", "seq", "embed")
