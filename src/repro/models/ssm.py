"""Mamba-2 (SSD, state-space duality) mixer — pure-JAX chunked algorithm.

y_t = C_t · h_t,   h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T

Computed chunk-wise (arXiv:2405.21060): quadratic attention-like intra-chunk
term + linear inter-chunk state recurrence, so cost is O(L·Q) instead of
O(L^2) and the whole thing is einsum/scan (GSPMD-partitionable). The Pallas
hot-path kernel lives in repro/kernels/ssd_scan.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.axes import hint
from repro.models.specs import MambaSpec
from repro.models.taps import tap


def init_mamba(key: jax.Array, d_model: int, spec: MambaSpec,
               dtype=jnp.float32) -> dict:
    ki, ko, kd = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(spec.d_inner)
    H = spec.n_heads
    # dt_bias: softplus^-1 of dt ~ U[1e-3, 0.1]
    dt = jnp.exp(jax.random.uniform(kd, (H,)) *
                 (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": (jax.random.normal(ki, (d_model, spec.in_dim)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(ko, (spec.conv_dim, spec.d_conv)) *
                   (1.0 / math.sqrt(spec.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((spec.conv_dim,), dtype),
        "A_log": jnp.log(1.0 + jax.random.uniform(kd, (H,)) * 15.0).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((spec.d_inner,), dtype),
        "out_proj": (jax.random.normal(ko, (spec.d_inner, d_model)) * s_out).astype(dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 carry: Optional[jax.Array] = None):
    """Depthwise causal conv1d. xbc: (B, L, C), w: (C, K). Returns (out, new_carry)."""
    B, L, C = xbc.shape
    K = w.shape[1]
    if carry is None:
        carry = jnp.zeros((B, K - 1, C), xbc.dtype)
    full = jnp.concatenate([carry, xbc], axis=1)            # (B, L+K-1, C)
    out = jnp.zeros((B, L, C), xbc.dtype)
    for k in range(K):
        out = out + full[:, k:k + L, :] * w[:, k].astype(xbc.dtype)
    new_carry = full[:, L:, :]
    return out + b.astype(xbc.dtype), new_carry


def ssd_chunked(xt: jax.Array, da: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD core.

    xt: (B, L, H, P) dt-scaled inputs; da: (B, L, H) log decays (dt*A, <=0);
    Bm, Cm: (B, L, N) (single group, broadcast over heads).
    Returns y: (B, L, H, P) and final state (B, H, P, N).
    """
    Bb, L_orig, H, P = xt.shape
    N = Bm.shape[-1]
    Q = min(chunk, L_orig)
    pad = (-L_orig) % Q
    if pad:
        # zero-pad the tail: da=0 -> decay 1, xt=0 -> no state contribution
        xt = jnp.pad(xt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    L = L_orig + pad
    nc = L // Q
    f32 = jnp.float32

    xt_c = xt.reshape(Bb, nc, Q, H, P)
    da_c = da.reshape(Bb, nc, Q, H).astype(f32)
    B_c = Bm.reshape(Bb, nc, Q, N)
    C_c = Cm.reshape(Bb, nc, Q, N)

    Lc = jnp.cumsum(da_c, axis=2)                           # (B,nc,Q,H)
    seg = jnp.exp(Lc[:, :, :, None, :] - Lc[:, :, None, :, :])   # (B,nc,Q,Q,H)
    idx = jnp.arange(Q)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    seg = jnp.where(causal, seg, 0.0)

    CB = jnp.einsum("bcin,bcjn->bcij", C_c, B_c,
                    preferred_element_type=f32)             # (B,nc,Q,Q)
    scores = CB[..., None] * seg                            # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(xt.dtype), xt_c)

    # Per-chunk end states
    decay_end = jnp.exp(Lc[:, :, -1:, :] - Lc)              # (B,nc,Q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                        decay_end, B_c.astype(f32), xt_c.astype(f32))

    # Inter-chunk recurrence
    chunk_decay = jnp.exp(Lc[:, :, -1, :])                  # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), f32)

    def step(h, inp):
        dec, s = inp                                        # (B,H), (B,H,P,N)
        h_new = h * dec[:, :, None, None] + s
        return h_new, h

    chunk_decay_t = jnp.moveaxis(chunk_decay, 1, 0)         # (nc,B,H)
    states_t = jnp.moveaxis(states, 1, 0)                   # (nc,B,H,P,N)
    h_final, h_prev = jax.lax.scan(step, h0.astype(f32),
                                   (chunk_decay_t, states_t))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         C_c.astype(f32), h_prev, jnp.exp(Lc)).astype(xt.dtype)
    y = (y_intra + y_inter).reshape(Bb, L, H, P)
    if pad:
        y = y[:, :L_orig]
    return y, h_final


def apply_mamba(params: dict, spec: MambaSpec, x: jax.Array,
                cache: Optional[dict] = None):
    """x: (B, L, d_model). cache: {'conv': (B,K-1,conv_dim), 'state': (B,H,P,N)}.

    Returns (out, new_cache)."""
    dtype = x.dtype
    B, L, _ = x.shape
    H, P, N = spec.n_heads, spec.head_dim, spec.d_state
    di = spec.d_inner

    tap("mamba_in", x)
    zxbcdt = hint(x @ params["in_proj"].astype(dtype),
                  "batch", "seq", "inner")                  # (B,L,in_dim)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + spec.conv_dim]
    dt_raw = zxbcdt[..., di + spec.conv_dim:]               # (B,L,H)

    conv_carry = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_carry)
    xbc = jax.nn.silu(xbc)
    xs = hint(xbc[..., :di].reshape(B, L, H, P),
              "batch", "seq", "heads", "head_dim")
    Bm = xbc[..., di:di + N]                                # (B,L,N) (groups=1)
    Cm = xbc[..., di + N:di + 2 * N]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                           # (H,) < 0
    da = dt * A                                             # (B,L,H)
    xt = xs * dt[..., None].astype(dtype)

    if cache is not None and L == 1:
        # single-step decode recurrence
        h = cache["state"]                                  # (B,H,P,N) f32
        dec = jnp.exp(da[:, 0, :])                          # (B,H)
        upd = jnp.einsum("bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                         xt[:, 0].astype(jnp.float32))
        h = h * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
        y = y[:, None].astype(dtype)                        # (B,1,H,P)
        new_state = h
    else:
        h0 = cache["state"] if cache is not None else None
        y, new_state = ssd_chunked(xt, da, Bm, Cm, spec.chunk, h0)

    y = y + params["D"].astype(dtype)[None, None, :, None] * xs
    y = hint(y, "batch", "seq", "heads", "head_dim")
    y = y.reshape(B, L, di)

    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(dtype)
    y = y * params["norm_scale"].astype(dtype)
    tap("mamba_out", y)
    out = hint(y @ params["out_proj"].astype(dtype), "batch", "seq", "embed")

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": new_state}
    return out, new_cache


def init_mamba_cache(batch: int, spec: MambaSpec, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, spec.conv_dim), dtype),
        "state": jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state),
                           jnp.float32),
    }
