"""Capacity-based top-k Mixture-of-Experts (GShard/Switch formulation).

Gather/scatter dispatch with fixed per-expert capacity so the whole layer is
a static-shape einsum program that XLA GSPMD can partition: experts shard
over the ``model`` mesh axis (all-to-alls inserted automatically), tokens
over ``data``.

Serving adds two occupancy-aware dispatch shapes on top (selected via
the ``expert_group_linear`` / ``expert_ragged_linear`` hooks):

* the grouped (capacity-slot) path threads per-(group, expert) kept
  counts to its hook as a ``row_live`` mask so the grouped kernel can
  skip experts with zero routed tokens and padded capacity slots;
* the ragged (MegaBlocks-style) path drops capacity slots entirely —
  :func:`build_ragged_dispatch` packs only routed tokens into a
  contiguous buffer of ``RAGGED_BLOCK_ROWS``-aligned per-expert
  segments (offsets from a cumsum of router counts), with a static
  :func:`ragged_rows_bound` row budget so the program stays
  fixed-shape under jit.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.axes import hint
from repro.models.specs import MoESpec
from repro.models.layers import activation, init_mlp, apply_mlp
from repro.models.specs import MLPSpec
from repro.models.taps import tap


def init_moe(key: jax.Array, d_model: int, spec: MoESpec, dtype=jnp.float32) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, F = spec.n_experts, spec.d_ff
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(F)
    p = {
        "router": (jax.random.normal(kr, (d_model, E)) * s_in).astype(dtype),
        "up": (jax.random.normal(ku, (E, d_model, F)) * s_in).astype(dtype),
        "down": (jax.random.normal(kd, (E, F, d_model)) * s_out).astype(dtype),
    }
    if spec.gated:
        p["gate"] = (jax.random.normal(kg, (E, d_model, F)) * s_in).astype(dtype)
    if spec.n_shared:
        shared_spec = MLPSpec(d_ff=F * spec.n_shared, act=spec.act, gated=spec.gated)
        p["shared"] = init_mlp(ks, d_model, shared_spec, dtype)
    return p


def capacity(spec: MoESpec, n_tokens: int) -> int:
    """Per-expert dispatch slots. Clamped to >= 1 *before* the sublane
    rounding: at tiny decode batches (or extreme capacity_factor / E
    combos) ``capacity_factor * top_k * n_tokens / n_experts`` rounds
    toward zero, and a zero capacity would silently drop every token."""
    c = max(1, int(math.ceil(
        spec.capacity_factor * spec.top_k * n_tokens / spec.n_experts)))
    return max(4, ((c + 3) // 4) * 4)


def n_groups(B: int, S: int) -> int:
    """Dispatch groups: align with the data-parallel batch sharding so the
    per-group scatter/gather stays shard-local under GSPMD (no global
    gather pathology). Groups follow the batch dim; tiny batches fall back
    to a single group."""
    return B


# M-tile height of the ragged expert-packed buffer: per-expert segments
# start on multiples of this so every ragged-kernel tile belongs to
# exactly one expert. Matches the kernel ops' RAGGED_BLOCK_ROWS (one
# sublane tile).
RAGGED_BLOCK_ROWS = 16


def ragged_rows_bound(n_experts: int, n_assign: int) -> int:
    """Static row budget for the ragged packed buffer: ``n_assign`` kept
    assignments at most, plus up to ``RAGGED_BLOCK_ROWS - 1`` alignment
    padding rows for each expert that can be non-empty, rounded up to a
    whole tile. Static in (E, top_k, tokens) so jit never retraces on
    occupancy."""
    A = RAGGED_BLOCK_ROWS
    m = n_assign + min(n_experts, n_assign) * (A - 1)
    return ((m + A - 1) // A) * A


def build_ragged_dispatch(flat_ids: jax.Array, keep: jax.Array,
                          pos: jax.Array, n_experts: int, m_max: int):
    """Layout of the ragged (MegaBlocks-style) expert batch.

    flat_ids / keep / pos: (G, s*K) per-group router assignments —
    expert id, capacity-kept mask, and within-(group, expert) position.
    Returns ``(dest, tile_expert, counts_e)``:

    * ``dest (G, s*K)`` — packed-buffer row of each assignment (the
      dump row ``m_max`` for capacity-dropped ones). Within expert
      ``e``, group ``g``'s kept rows land at
      ``offset[e] + sum_{g'<g} counts[g', e] + pos`` — contiguous per
      expert, group-major, in capacity order, so the layout is a pure
      function of the routing (not of arrival order).
    * ``tile_expert (m_max / RAGGED_BLOCK_ROWS,)`` — owning expert per
      M-tile via searchsorted over the aligned cumsum offsets; ``-1``
      past the packed total.
    * ``counts_e (E,)`` — kept assignments per expert (the router
      counts whose cumsum drives the offsets).
    """
    A = RAGGED_BLOCK_ROWS
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)
    kept = onehot * keep[..., None].astype(jnp.int32)
    count_ge = kept.sum(axis=1)                         # (G, E)
    inter = jnp.cumsum(count_ge, axis=0) - count_ge     # rows from earlier groups
    counts_e = count_ge.sum(axis=0)                     # (E,)
    seg = ((counts_e + A - 1) // A) * A                 # tile-aligned segments
    ends = jnp.cumsum(seg)
    off = ends - seg                                    # (E,) segment starts
    inter_gi = jnp.take_along_axis(inter, flat_ids, axis=1)
    dest = jnp.where(keep, off[flat_ids] + inter_gi + pos, m_max)
    tile_starts = jnp.arange(m_max // A, dtype=jnp.int32) * A
    e_t = jnp.searchsorted(ends, tile_starts, side="right")
    tile_expert = jnp.where(e_t < n_experts, e_t, -1).astype(jnp.int32)
    return dest, tile_expert, counts_e


def apply_moe(params: dict, spec: MoESpec, x: jax.Array,
              expert_linear=None, expert_group_linear=None,
              expert_ragged_linear=None):
    """x: (B, S, d). Returns (y, aux_loss).

    Grouped capacity dispatch (GShard/T5X style): tokens are routed within
    their group only; scatter/gather carry a leading group batch-dim, so
    XLA partitions them along 'data' instead of emitting global gathers.

    ``expert_linear``: optional ``(name, e, x2, w) -> y2`` override for
    the per-expert matmuls (``x2``: the expert's flattened dispatch slots,
    ``w``: that expert's 2-D weight) — the serving block-sparse fallback
    path runs each expert's slot batch through that expert's tile plan
    here, one kernel launch per expert.

    ``expert_group_linear``: optional ``(name, xs, ws, row_live) -> ys``
    override for the *stacked* expert matmuls (``xs``: (E, G·C, d) all
    experts' flattened dispatch slots, ``ws``: the (E, d_in, d_out)
    weight stack, ``row_live``: (E, G·C) bool — which slots hold a
    routed token, from the router's kept counts) — the grouped
    block-sparse kernel executes all E experts in ONE launch here,
    skipping experts/slot-blocks ``row_live`` marks empty. Takes
    precedence over ``expert_linear`` when both are given.

    ``expert_ragged_linear``: optional ``(name, xp, ws, tile_expert) ->
    yp`` override taking a *ragged* expert batch instead of capacity
    slots: ``xp (m_max, d_in)`` packs only routed tokens into
    tile-aligned per-expert segments (see :func:`build_ragged_dispatch`)
    and ``tile_expert`` names each M-tile's owner. Compute is
    proportional to tokens actually routed, not E·capacity. Highest
    precedence of the three.

    Every path computes each routed token's expert matmuls with the same
    per-row dot products and combine weights, so outputs are
    bitwise-identical across dense / loop / grouped / ragged; the
    grouped and ragged overrides additionally *skip* unoccupied work.
    The default path is the stacked einsum (and the only path that feeds
    the calibration taps, which profile the dense model).
    """
    dtype = x.dtype
    B, S, d = x.shape
    E, K = spec.n_experts, spec.top_k
    G = n_groups(B, S)
    s = (B * S) // G
    C = capacity(spec, s)
    xg = x.reshape(G, s, d)

    logits = (xg @ params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, s, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)         # (G, s, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # Position of each (token, k) assignment within its expert, per group.
    flat_ids = expert_ids.reshape(G, s * K)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)   # (G, sK, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # (G, sK)
    keep = pos < C
    slot = jnp.where(keep, flat_ids * C + pos, E * C)       # drop -> last

    src = jnp.repeat(xg, K, axis=1)                         # (G, sK, d)

    if expert_ragged_linear is not None:
        # Ragged dispatch: pack only routed tokens, no capacity slots.
        m_max = ragged_rows_bound(E, G * s * K)
        dest, tile_expert, _ = build_ragged_dispatch(flat_ids, keep, pos,
                                                     E, m_max)
        flat_dest = dest.reshape(-1)
        xp = (jnp.zeros((m_max + 1, d), dtype)
              .at[flat_dest].add(src.reshape(-1, d)))[:m_max]
        up = expert_ragged_linear("up", xp, params["up"].astype(dtype),
                                  tile_expert)
        if spec.gated:
            g = activation(spec.act, expert_ragged_linear(
                "gate", xp, params["gate"].astype(dtype), tile_expert))
            h = g * up
        else:
            h = activation(spec.act, up)
        out = expert_ragged_linear("down", h, params["down"].astype(dtype),
                                   tile_expert)
        # Combine: dropped assignments carry dest == m_max, one past the
        # packed buffer, so take's fill handles them. (A -1 sentinel
        # would silently WRAP to the last row — jnp.take only fills for
        # indices >= n.)
        gathered = jnp.take(out, dest.reshape(-1), axis=0, mode="fill",
                            fill_value=0)
        gathered = gathered.reshape(G, s, K, d)
    else:
        # Dispatch: per-group scatter into (G, E*C+1, d) slot buffers.
        buf = jax.vmap(lambda sl, sr: jnp.zeros((E * C + 1, d), dtype)
                       .at[sl].add(sr))(slot, src)
        slots = buf[:, :E * C].reshape(G, E, C, d)
        slots = hint(slots, "batch", "experts", None, None)

        # Expert FFN on (G, E, C, d)
        if expert_group_linear is not None:
            # stacked-expert matmul override (grouped block-sparse
            # serving): all E experts' slot batches run through one
            # kernel launch, with router occupancy marking live slots
            count_ge = (onehot * keep[..., None].astype(jnp.int32)
                        ).sum(axis=1)                       # (G, E)
            row_live = (jnp.arange(C)[None, None, :]
                        < count_ge.T[:, :, None])           # (E, G, C)
            row_live = row_live.reshape(E, G * C)
            xs = slots.transpose(1, 0, 2, 3).reshape(E, G * C, d)
            up = expert_group_linear("up", xs, params["up"].astype(dtype),
                                     row_live)
            if spec.gated:
                g = activation(spec.act, expert_group_linear(
                    "gate", xs, params["gate"].astype(dtype), row_live))
                h = g * up
            else:
                h = activation(spec.act, up)
            out = expert_group_linear("down", h,
                                      params["down"].astype(dtype),
                                      row_live)
            out_slots = out.reshape(E, G, C, d).transpose(1, 0, 2, 3)
        elif expert_linear is None:
            tap("moe_in", slots, channel_axes=(1, 3), expert_first=True)
            up = jnp.einsum("gecd,edf->gecf", slots,
                            params["up"].astype(dtype))
            if spec.gated:
                g = activation(spec.act, jnp.einsum(
                    "gecd,edf->gecf", slots, params["gate"].astype(dtype)))
                h = g * up
            else:
                h = activation(spec.act, up)
            tap("moe_down", h, channel_axes=(1, 3), expert_first=True)
            out_slots = jnp.einsum("gecf,efd->gecd", h,
                                   params["down"].astype(dtype))
        else:
            # per-expert matmul override (block-sparse serving): each
            # expert's C-slot batch runs through its own kernel plan
            outs = []
            for e in range(E):
                xe = slots[:, e].reshape(G * C, d)
                up = expert_linear("up", e, xe,
                                   params["up"][e].astype(dtype))
                if spec.gated:
                    g = activation(spec.act, expert_linear(
                        "gate", e, xe, params["gate"][e].astype(dtype)))
                    h = g * up
                else:
                    h = activation(spec.act, up)
                out = expert_linear("down", e, h,
                                    params["down"][e].astype(dtype))
                outs.append(out.reshape(G, C, d))
            out_slots = jnp.stack(outs, axis=1)
        out_slots = hint(out_slots, "batch", "experts", None, None)

        # Combine: per-group gather; dropped assignments contribute 0.
        # ``slot`` is already E*C (one past flat_out) for dropped rows,
        # which take's fill mode zeroes; never remap drops to -1 — fill
        # mode only catches indices >= n, so -1 would WRAP to the last
        # expert's last capacity slot and leak that token's output into
        # every dropped assignment.
        flat_out = out_slots.reshape(G, E * C, d)
        gathered = jax.vmap(lambda fo, sl: jnp.take(
            fo, sl, axis=0, mode="fill", fill_value=0))(
            flat_out, slot)                                 # (G, sK, d)
        gathered = gathered.reshape(G, s, K, d)
    y = jnp.einsum("gskd,gsk->gsd", gathered, gate_vals.astype(dtype))

    if "shared" in params:
        shared_spec = MLPSpec(d_ff=params["shared"]["up"].shape[1],
                              act=spec.act, gated=spec.gated)
        y = y + apply_mlp(params["shared"], shared_spec,
                          xg.reshape(G * s, d)).reshape(G, s, d)
    return y.reshape(B, S, d), aux
