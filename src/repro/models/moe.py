"""Capacity-based top-k Mixture-of-Experts (GShard/Switch formulation).

Gather/scatter dispatch with fixed per-expert capacity so the whole layer is
a static-shape einsum program that XLA GSPMD can partition: experts shard
over the ``model`` mesh axis (all-to-alls inserted automatically), tokens
over ``data``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.axes import hint
from repro.models.specs import MoESpec
from repro.models.layers import activation, init_mlp, apply_mlp
from repro.models.specs import MLPSpec
from repro.models.taps import tap


def init_moe(key: jax.Array, d_model: int, spec: MoESpec, dtype=jnp.float32) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, F = spec.n_experts, spec.d_ff
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(F)
    p = {
        "router": (jax.random.normal(kr, (d_model, E)) * s_in).astype(dtype),
        "up": (jax.random.normal(ku, (E, d_model, F)) * s_in).astype(dtype),
        "down": (jax.random.normal(kd, (E, F, d_model)) * s_out).astype(dtype),
    }
    if spec.gated:
        p["gate"] = (jax.random.normal(kg, (E, d_model, F)) * s_in).astype(dtype)
    if spec.n_shared:
        shared_spec = MLPSpec(d_ff=F * spec.n_shared, act=spec.act, gated=spec.gated)
        p["shared"] = init_mlp(ks, d_model, shared_spec, dtype)
    return p


def capacity(spec: MoESpec, n_tokens: int) -> int:
    c = int(math.ceil(spec.capacity_factor * spec.top_k * n_tokens / spec.n_experts))
    return max(4, ((c + 3) // 4) * 4)


def n_groups(B: int, S: int) -> int:
    """Dispatch groups: align with the data-parallel batch sharding so the
    per-group scatter/gather stays shard-local under GSPMD (no global
    gather pathology). Groups follow the batch dim; tiny batches fall back
    to a single group."""
    return B


def apply_moe(params: dict, spec: MoESpec, x: jax.Array,
              expert_linear=None, expert_group_linear=None):
    """x: (B, S, d). Returns (y, aux_loss).

    Grouped capacity dispatch (GShard/T5X style): tokens are routed within
    their group only; scatter/gather carry a leading group batch-dim, so
    XLA partitions them along 'data' instead of emitting global gathers.

    ``expert_linear``: optional ``(name, e, x2, w) -> y2`` override for
    the per-expert matmuls (``x2``: the expert's flattened dispatch slots,
    ``w``: that expert's 2-D weight) — the serving block-sparse fallback
    path runs each expert's slot batch through that expert's tile plan
    here, one kernel launch per expert.

    ``expert_group_linear``: optional ``(name, xs, ws) -> ys`` override
    for the *stacked* expert matmuls (``xs``: (E, G·C, d) all experts'
    flattened dispatch slots, ``ws``: the (E, d_in, d_out) weight stack)
    — the grouped block-sparse kernel executes all E experts in ONE
    launch here. Takes precedence over ``expert_linear`` when both are
    given.

    All E experts compute over their capacity slots on every path
    (exactly like the stacked einsum); the overrides save zero tiles,
    not expert selection. The default path is the stacked einsum (and
    the only path that feeds the calibration taps, which profile the
    dense model).
    """
    dtype = x.dtype
    B, S, d = x.shape
    E, K = spec.n_experts, spec.top_k
    G = n_groups(B, S)
    s = (B * S) // G
    C = capacity(spec, s)
    xg = x.reshape(G, s, d)

    logits = (xg @ params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, s, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)         # (G, s, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # Position of each (token, k) assignment within its expert, per group.
    flat_ids = expert_ids.reshape(G, s * K)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)   # (G, sK, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # (G, sK)
    keep = pos < C
    slot = jnp.where(keep, flat_ids * C + pos, E * C)       # drop -> last

    # Dispatch: per-group scatter into (G, E*C+1, d) slot buffers.
    src = jnp.repeat(xg, K, axis=1)                         # (G, sK, d)
    buf = jax.vmap(lambda sl, sr: jnp.zeros((E * C + 1, d), dtype)
                   .at[sl].add(sr))(slot, src)
    slots = buf[:, :E * C].reshape(G, E, C, d)
    slots = hint(slots, "batch", "experts", None, None)

    # Expert FFN on (G, E, C, d)
    if expert_group_linear is not None:
        # stacked-expert matmul override (grouped block-sparse serving):
        # all E experts' slot batches run through one kernel launch
        xs = slots.transpose(1, 0, 2, 3).reshape(E, G * C, d)
        up = expert_group_linear("up", xs, params["up"].astype(dtype))
        if spec.gated:
            g = activation(spec.act, expert_group_linear(
                "gate", xs, params["gate"].astype(dtype)))
            h = g * up
        else:
            h = activation(spec.act, up)
        out = expert_group_linear("down", h, params["down"].astype(dtype))
        out_slots = out.reshape(E, G, C, d).transpose(1, 0, 2, 3)
    elif expert_linear is None:
        tap("moe_in", slots, channel_axes=(1, 3), expert_first=True)
        up = jnp.einsum("gecd,edf->gecf", slots, params["up"].astype(dtype))
        if spec.gated:
            g = activation(spec.act, jnp.einsum(
                "gecd,edf->gecf", slots, params["gate"].astype(dtype)))
            h = g * up
        else:
            h = activation(spec.act, up)
        tap("moe_down", h, channel_axes=(1, 3), expert_first=True)
        out_slots = jnp.einsum("gecf,efd->gecd", h,
                               params["down"].astype(dtype))
    else:
        # per-expert matmul override (block-sparse serving): each expert's
        # C-slot batch runs through its own kernel plan
        outs = []
        for e in range(E):
            xe = slots[:, e].reshape(G * C, d)
            up = expert_linear("up", e, xe, params["up"][e].astype(dtype))
            if spec.gated:
                g = activation(spec.act, expert_linear(
                    "gate", e, xe, params["gate"][e].astype(dtype)))
                h = g * up
            else:
                h = activation(spec.act, up)
            out = expert_linear("down", e, h,
                                params["down"][e].astype(dtype))
            outs.append(out.reshape(G, C, d))
        out_slots = jnp.stack(outs, axis=1)
    out_slots = hint(out_slots, "batch", "experts", None, None)

    # Combine: per-group gather; dropped assignments contribute 0.
    flat_out = out_slots.reshape(G, E * C, d)
    gathered = jax.vmap(lambda fo, sl: jnp.take(
        fo, sl, axis=0, mode="fill", fill_value=0))(
        flat_out, jnp.where(keep, slot, -1))                # (G, sK, d)
    gathered = gathered.reshape(G, s, K, d)
    y = jnp.einsum("gskd,gsk->gsd", gathered, gate_vals.astype(dtype))

    if "shared" in params:
        shared_spec = MLPSpec(d_ff=params["shared"]["up"].shape[1],
                              act=spec.act, gated=spec.gated)
        y = y + apply_mlp(params["shared"], shared_spec,
                          xg.reshape(G * s, d)).reshape(G, s, d)
    return y.reshape(B, S, d), aux
