"""Activation taps: capture per-input-channel sum-of-squares at every
projection input during a forward pass (the ``||A||_2`` term of Eq. 5).

Layer applies call :func:`tap`; a collector is active only inside
``collecting()``. Because taps are appended during a single jit trace and
returned from the same trace, this is jit-safe.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax.numpy as jnp

_COLLECTOR: Optional[list] = None
_MODE: str = "ssq"


def _ssq_stat(x32, reduce_axes):
    return jnp.sum(jnp.square(x32), axis=reduce_axes)


def _gram_stat(x32, x_shape, keep, expert_first: bool):
    if expert_first:
        # per-expert Hessian: (..., E, ..., d) -> (E, d, d)
        xe = jnp.moveaxis(x32, keep[0], 0)
        dims = 1
        for a in keep[1:]:
            dims *= x_shape[a]
        flat = xe.reshape(xe.shape[0], -1, dims)
        return jnp.einsum("ecd,ecf->edf", flat, flat)
    dims = 1
    for a in keep:
        dims *= x_shape[a]
    flat = x32.reshape(-1, dims)
    return flat.T @ flat


def tap(name: str, x, channel_axes=(-1,), expert_first: bool = False) -> None:
    """Record a statistic of ``x`` over all non-channel axes.

    mode 'ssq': per-channel sum of squares (-> ||A||_2 for Eq. 5).
    mode 'hessian': X^T X over flattened channel axes (SparseGPT).
    mode 'both': (ssq, X^T X) tuple — one forward pass supplies both the
    POD ranking stats and the SparseGPT Hessians (profile-once).
    channel_axes: axes kept (the projection's input-feature axes); all
    other axes (batch / seq / capacity) are reduced. expert_first: the
    first channel axis is a category (per-expert stats), not a feature.
    """
    if _COLLECTOR is None:
        return
    keep = sorted(a % x.ndim for a in channel_axes)
    reduce_axes = tuple(a for a in range(x.ndim) if a not in keep)
    x32 = x.astype(jnp.float32)
    if _MODE == "ssq":
        stat = _ssq_stat(x32, reduce_axes)
    elif _MODE == "hessian":
        stat = _gram_stat(x32, x.shape, keep, expert_first)
    elif _MODE == "both":
        stat = (_ssq_stat(x32, reduce_axes),
                _gram_stat(x32, x.shape, keep, expert_first))
    else:
        raise ValueError(f"unknown tap mode {_MODE!r}")
    _COLLECTOR.append((name, stat))


@contextlib.contextmanager
def collecting(mode: str = "ssq"):
    global _COLLECTOR, _MODE
    prev, prev_mode = _COLLECTOR, _MODE
    _COLLECTOR, _MODE = [], mode
    try:
        yield _COLLECTOR
    finally:
        _COLLECTOR, _MODE = prev, prev_mode
