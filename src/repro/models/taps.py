"""Activation taps: capture per-input-channel sum-of-squares at every
projection input during a forward pass (the ``||A||_2`` term of Eq. 5).

Layer applies call :func:`tap`; a collector is active only inside
``collecting()``. Because taps are appended during a single jit trace and
returned from the same trace, this is jit-safe.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax.numpy as jnp

_COLLECTOR: Optional[list] = None
_MODE: str = "ssq"


def tap(name: str, x, channel_axes=(-1,), expert_first: bool = False) -> None:
    """Record a statistic of ``x`` over all non-channel axes.

    mode 'ssq': per-channel sum of squares (-> ||A||_2 for Eq. 5).
    mode 'hessian': X^T X over flattened channel axes (SparseGPT).
    channel_axes: axes kept (the projection's input-feature axes); all
    other axes (batch / seq / capacity) are reduced. expert_first: the
    first channel axis is a category (per-expert stats), not a feature.
    """
    if _COLLECTOR is None:
        return
    keep = sorted(a % x.ndim for a in channel_axes)
    reduce_axes = tuple(a for a in range(x.ndim) if a not in keep)
    x32 = x.astype(jnp.float32)
    if _MODE == "ssq":
        stat = jnp.sum(jnp.square(x32), axis=reduce_axes)
    else:
        if expert_first:
            # per-expert Hessian: (..., E, ..., d) -> (E, d, d)
            e_ax, feat_axes = keep[0], keep[1:]
            xe = jnp.moveaxis(x32, e_ax, 0)
            feat_axes = [a if a < e_ax else a for a in feat_axes]
            dims = 1
            for a in keep[1:]:
                dims *= x.shape[a]
            # move feature axes last, flatten the middle
            xe = jnp.moveaxis(xe, -1, -1)
            flat = xe.reshape(xe.shape[0], -1, dims)
            stat = jnp.einsum("ecd,ecf->edf", flat, flat)
        else:
            dims = 1
            for a in keep:
                dims *= x.shape[a]
            flat = x32.reshape(-1, dims)
            stat = flat.T @ flat
    _COLLECTOR.append((name, stat))


@contextlib.contextmanager
def collecting(mode: str = "ssq"):
    global _COLLECTOR, _MODE
    prev, prev_mode = _COLLECTOR, _MODE
    _COLLECTOR, _MODE = [], mode
    try:
        yield _COLLECTOR
    finally:
        _COLLECTOR, _MODE = prev, prev_mode
