"""Composable decoder model: embedding -> [LayerSpec...] -> norm -> LM head.

Giant configs scan over the repeating layer pattern (HLO size O(pattern));
small / structurally-pruned models unroll with per-layer parameter shapes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.axes import hint
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.specs import (AttentionSpec, LayerSpec, ModelConfig,
                                MoESpec)


# ---------------------------------------------------------------- init

def init_block(key: jax.Array, cfg: ModelConfig, spec: LayerSpec,
               dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"norm1": L.init_norm(cfg.norm, cfg.d_model, dtype)}
    if isinstance(spec.mixer, AttentionSpec):
        p["attn"] = L.init_attention(k1, cfg.d_model, spec.mixer, dtype)
    else:
        p["mamba"] = SSM.init_mamba(k1, cfg.d_model, spec.mixer, dtype)
    if spec.ffn is not None:
        p["norm2"] = L.init_norm(cfg.norm, cfg.d_model, dtype)
        if isinstance(spec.ffn, MoESpec):
            p["moe"] = MOE.init_moe(k2, cfg.d_model, spec.ffn, dtype)
        else:
            p["mlp"] = L.init_mlp(k2, cfg.d_model, spec.ffn, dtype)
    return p


def init_model(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 4)
    Vp, d = cfg.padded_vocab, cfg.d_model
    params: dict[str, Any] = {
        "embed": {"table": (jax.random.normal(keys[0], (Vp, d)) * 0.02).astype(dtype)},
        "final_norm": L.init_norm(cfg.norm, d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(keys[1], (d, Vp)) * (d ** -0.5)).astype(dtype)}

    if cfg.scan_layers:
        # stacked params: leaves get a leading n_periods axis per pattern slot
        def init_period(k):
            ks = jax.random.split(k, len(cfg.pattern))
            return tuple(init_block(ks[j], cfg, spec, dtype)
                         for j, spec in enumerate(cfg.pattern))
        period_keys = jax.random.split(keys[2], cfg.n_periods)
        stacked = jax.vmap(init_period)(period_keys)
        params["blocks"] = stacked
    else:
        ks = jax.random.split(keys[2], cfg.n_layers)
        params["blocks"] = [init_block(ks[i], cfg, cfg.layer(i), dtype)
                            for i in range(cfg.n_layers)]
    return params


# ---------------------------------------------------------------- cache

def init_block_cache(batch: int, s_max: int, spec: LayerSpec,
                     dtype=jnp.bfloat16) -> dict:
    if isinstance(spec.mixer, AttentionSpec):
        return {"attn": L.init_attention_cache(batch, s_max, spec.mixer, dtype)}
    return {"mamba": SSM.init_mamba_cache(batch, spec.mixer, dtype)}


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16):
    if cfg.scan_layers:
        def one_period(_):
            return tuple(init_block_cache(batch, s_max, spec, dtype)
                         for spec in cfg.pattern)
        return jax.vmap(one_period)(jnp.arange(cfg.n_periods))
    return [init_block_cache(batch, s_max, cfg.layer(i), dtype)
            for i in range(cfg.n_layers)]


# ------------------------------------------------------------- slot pool

def init_cache_pool(cfg: ModelConfig, max_slots: int, max_seq: int,
                    dtype=jnp.bfloat16):
    """A fixed ``(max_slots, max_seq)`` KV pool for continuous batching.

    The pool is an ordinary cache whose batch axis is the slot axis;
    sequences are prefillled into individual slots (``write_cache_slot``)
    and decoded at per-slot offsets (vector ``cache_index`` in
    ``forward``). Per-slot length/active bookkeeping lives host-side in
    the scheduler. Unrolled configs only: the slot axis must be the
    leading axis of every cache leaf.
    """
    if cfg.scan_layers:
        raise ValueError("cache pools require an unrolled config "
                         "(cfg.replace(scan_layers=False))")
    return init_cache(cfg, max_slots, max_seq, dtype)


def init_paged_pool(cfg: ModelConfig, n_blocks: int, block_size: int,
                    dtype=jnp.bfloat16):
    """A paged KV pool: per attention layer, one ``(n_blocks + 1,
    block_size, n_kv, D)`` arena of fixed-size KV blocks shared by every
    request through per-request block tables (``block_tables`` in
    ``forward``), instead of one contiguous ``max_seq`` region per slot.

    The extra last block (index ``n_blocks``) is the *scratch* block:
    right-padded prefill positions and inactive decode slots write there
    so padding never corrupts a live block; the host-side allocator
    (``repro.serve.paging.BlockAllocator``) never hands it out.

    Attention-only, unrolled configs: an SSM mixer's state is recurrent,
    not positional, so it has nothing to page.
    """
    if cfg.scan_layers:
        raise ValueError("paged pools require an unrolled config "
                         "(cfg.replace(scan_layers=False))")
    pool = []
    for i in range(cfg.n_layers):
        spec = cfg.layer(i)
        if not isinstance(spec.mixer, AttentionSpec):
            raise ValueError("paged KV pools support attention mixers "
                             f"only (layer {i} is {type(spec.mixer).__name__})")
        pool.append({"attn": L.init_paged_attention_cache(
            n_blocks + 1, block_size, spec.mixer, dtype)})
    return pool


def copy_pool_block(pool, src, dst):
    """Copy arena block ``src`` -> ``dst`` in every layer of a paged
    pool — the device half of copy-on-write when a writer would touch a
    block shared between requests. jit-safe (``src``/``dst`` may be
    traced)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    return jax.tree.map(
        lambda leaf: jax.lax.dynamic_update_slice_in_dim(
            leaf, jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=0),
            dst, axis=0),
        pool)


def write_cache_slot(pool, row, slot):
    """Scatter a batch-1 cache ``row`` into ``pool`` at slot ``slot``.

    ``row`` is the cache produced by a B=1 prefill; every leaf's leading
    axis is the batch/slot axis. jit-safe (``slot`` may be traced).
    """
    return jax.tree.map(
        lambda p, r: jax.lax.dynamic_update_slice_in_dim(
            p, r.astype(p.dtype), slot, axis=0),
        pool, row)


# ---------------------------------------------------------------- forward

def apply_block(block_params: dict, cfg: ModelConfig, spec: LayerSpec,
                x: jax.Array, positions: jax.Array,
                cache: Optional[dict], cache_index,
                layer: int = 0, mlp_apply=None,
                block_tables: Optional[jax.Array] = None,
                n_valid: Optional[jax.Array] = None,
                paged_kernel: bool = False, interpret: bool = True):
    h = L.apply_norm(block_params["norm1"], cfg.norm, x)
    new_cache = {}
    if isinstance(spec.mixer, AttentionSpec):
        mix, nc = L.apply_attention(
            block_params["attn"], spec.mixer, h, positions,
            cache["attn"] if cache is not None else None, cache_index,
            block_tables=block_tables, n_valid=n_valid,
            paged_kernel=paged_kernel, interpret=interpret)
        if nc is not None:
            new_cache["attn"] = nc
    else:
        mix, nc = SSM.apply_mamba(
            block_params["mamba"], spec.mixer, h,
            cache["mamba"] if cache is not None else None)
        if nc is not None:
            new_cache["mamba"] = nc
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn is not None:
        h = L.apply_norm(block_params["norm2"], cfg.norm, x)
        if mlp_apply is not None:
            # serving fast path: the hook sees every FFN (MLP and MoE)
            # spec and dispatches dense-vs-sparse per projection
            y = mlp_apply(block_params, spec.ffn, h, layer)
        elif isinstance(spec.ffn, MoESpec):
            y, aux = MOE.apply_moe(block_params["moe"], spec.ffn, h)
        else:
            y = L.apply_mlp(block_params["mlp"], spec.ffn, h)
        x = x + y
    return x, (new_cache if cache is not None else None), aux


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            frontend_embeds: Optional[jax.Array] = None,
            cache=None, cache_index=None,
            compute_dtype=jnp.bfloat16, mlp_apply=None,
            block_tables: Optional[jax.Array] = None,
            n_valid: Optional[jax.Array] = None,
            paged_kernel: bool = False, interpret: bool = True):
    """Returns (logits, new_cache, aux_loss).

    tokens: (B, S) int32. frontend_embeds: (B, F, d) stub embeddings that
    replace the first F token embeddings (VLM patches / audio frames).
    cache + cache_index: decode mode (tokens are the new step(s));
    cache_index is a scalar or a per-sequence (B,) vector (slot pool).
    block_tables: (B, max_blocks) int32 — ``cache`` is a paged pool
    (``init_paged_pool``) and each sequence's KV rows are scattered /
    gathered through its block-table row; ``n_valid`` (B,) masks
    right-padded positions of a padded (chunked) prefill into the
    scratch block. Unrolled configs only. ``paged_kernel`` routes paged
    S==1 steps through the fused Pallas decode kernel (``interpret``
    selects its CPU interpret mode) instead of the gather path.
    mlp_apply: optional ``(block_params, ffn_spec, x, layer) -> y``
    override for FFN layers (``ffn_spec`` is an ``MLPSpec`` or
    ``MoESpec``) — the serving block-sparse fast path; MoE layers run
    each expert's capacity-slot batch through its per-expert plan and
    drop the aux loss (inference-only). Unrolled configs only (the
    layer index must be static).
    """
    B, S = tokens.shape
    if mlp_apply is not None and cfg.scan_layers:
        raise ValueError("mlp_apply needs static layer indices; use an "
                         "unrolled config (scan_layers=False)")
    if block_tables is not None and cfg.scan_layers:
        raise ValueError("paged caches need an unrolled config "
                         "(scan_layers=False)")
    if positions is None:
        if cache_index is not None:
            ci = jnp.asarray(cache_index, jnp.int32)
            ci = ci[:, None] if ci.ndim else ci
            positions = ci + jnp.arange(S, dtype=jnp.int32)[None, :]
            positions = jnp.broadcast_to(positions, (B, S))
        else:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(compute_dtype)
    x = hint(x, "batch", "seq", "embed")
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    if frontend_embeds is not None:
        F = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(compute_dtype), x[:, F:]],
                            axis=1)

    aux_total = jnp.zeros((), jnp.float32)

    x = hint(x, "batch", "residual_seq", "embed")
    if cfg.scan_layers:
        def period_body(carry, xs):
            xh, aux = carry
            block_params, block_cache = xs
            new_caches = []
            for j, spec in enumerate(cfg.pattern):
                cj = block_cache[j] if block_cache is not None else None
                xh, ncj, a = apply_block(block_params[j], cfg, spec, xh,
                                         positions, cj, cache_index)
                aux = aux + a
                new_caches.append(ncj)
            # SP: the scan carry (= remat-saved activation) stays
            # seq-sharded between layers when 'residual_seq' is mapped
            xh = hint(xh, "batch", "residual_seq", "embed")
            return (xh, aux), (tuple(new_caches)
                               if block_cache is not None else 0)

        body = period_body
        if cfg.remat:
            body = jax.checkpoint(
                period_body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), new_cache = jax.lax.scan(
            body, (x, aux_total), (params["blocks"], cache))
        if cache is None:
            new_cache = None
    else:
        new_cache = [] if cache is not None else None
        for i in range(cfg.n_layers):
            ci = cache[i] if cache is not None else None
            spec_i = cfg.layer(i)

            def body(bp, xh, c, spec=spec_i, layer=i):
                return apply_block(bp, cfg, spec, xh, positions, c,
                                   cache_index, layer=layer,
                                   mlp_apply=mlp_apply,
                                   block_tables=block_tables,
                                   n_valid=n_valid,
                                   paged_kernel=paged_kernel,
                                   interpret=interpret)
            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, nci, a = body(params["blocks"][i], x, ci)
            x = hint(x, "batch", "residual_seq", "embed")
            aux_total = aux_total + a
            if cache is not None:
                new_cache.append(nci)

    x = L.apply_norm(params["final_norm"], cfg.norm, x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["table"].astype(compute_dtype))
    else:
        logits = x @ params["lm_head"]["w"].astype(compute_dtype)
    return logits, new_cache, aux_total


# ---------------------------------------------------------------- losses

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab: Optional[int] = None) -> jax.Array:
    """Mean next-token CE. logits: (B,S,Vp) (padded vocab ok), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        pad = logits.shape[-1] - vocab
        neg = jnp.full((pad,), -1e30, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab,), jnp.float32), neg])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(params: dict, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, frontend_embeds=None,
            compute_dtype=jnp.bfloat16, aux_weight: float = 0.01):
    logits, _, aux = forward(params, cfg, tokens,
                             frontend_embeds=frontend_embeds,
                             compute_dtype=compute_dtype)
    ce = cross_entropy(logits, labels, cfg.vocab)
    return ce + aux_weight * aux, (ce, aux)
