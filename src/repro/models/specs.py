"""Model configuration dataclasses.

A model is a stack of :class:`LayerSpec`, each combining a *mixer*
(attention or Mamba-2 SSD) and an optional *ffn* (dense MLP or MoE). Large
configs express the stack as a repeating ``pattern`` scanned ``n_periods``
times (keeps HLO size O(pattern) instead of O(depth)); small / pruned models
unroll with per-layer specs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Union


@dataclass(frozen=True)
class AttentionSpec:
    n_q: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    # Sliding window (tokens); None = full attention.
    window: Optional[int] = None

    @property
    def q_dim(self) -> int:
        return self.n_q * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim


@dataclass(frozen=True)
class MambaSpec:
    """Mamba-2 (SSD) mixer."""
    d_inner: int
    d_state: int = 128
    head_dim: int = 64
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        # conv runs over (x, B, C) channels
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_dim(self) -> int:
        # in_proj emits [z, x, B, C, dt]
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


@dataclass(frozen=True)
class MLPSpec:
    d_ff: int
    act: str = "silu"       # silu | gelu | relu2
    gated: bool = True


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int
    act: str = "silu"
    gated: bool = True
    n_shared: int = 0            # shared (always-on) experts, e.g. Llama-4
    capacity_factor: float = 1.25


MixerSpec = Union[AttentionSpec, MambaSpec]
FFNSpec = Union[MLPSpec, MoESpec]


@dataclass(frozen=True)
class LayerSpec:
    mixer: MixerSpec
    ffn: Optional[FFNSpec]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    pattern: tuple            # tuple[LayerSpec, ...] — the repeating unit
    n_periods: int
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    tie_embeddings: bool = False
    scan_layers: bool = True  # lax.scan over periods (giant configs)
    remat: bool = True
    frontend: Optional[str] = None      # None | 'vision' | 'audio'
    frontend_frac: float = 0.25         # fraction of positions fed by frontend
    vocab_pad_multiple: int = 256
    embed_scale: bool = False           # gemma-style sqrt(d) embedding scale
    max_seq: int = 8192                 # informational (configs override shapes)
    arch_class: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    subquadratic: bool = False          # eligible for long_500k

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n_periods

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    def layer(self, i: int) -> LayerSpec:
        return self.pattern[i % len(self.pattern)]

    def layers(self):
        return [self.layer(i) for i in range(self.n_layers)]

    def unrolled(self) -> "ModelConfig":
        """Per-layer (non-scanned) variant: pattern = full layer list."""
        return dataclasses.replace(
            self, pattern=tuple(self.layers()), n_periods=1, scan_layers=False)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------- (de)serialization

_SPEC_KINDS = {
    "AttentionSpec": AttentionSpec,
    "MambaSpec": MambaSpec,
    "MLPSpec": MLPSpec,
    "MoESpec": MoESpec,
}


def _spec_to_dict(spec) -> Optional[dict]:
    if spec is None:
        return None
    d = {f.name: getattr(spec, f.name) for f in dataclasses.fields(spec)}
    d["kind"] = type(spec).__name__
    return d


def _spec_from_dict(d: Optional[dict]):
    if d is None:
        return None
    d = dict(d)
    kind = d.pop("kind")
    if kind not in _SPEC_KINDS:
        raise ValueError(f"unknown spec kind {kind!r}")
    return _SPEC_KINDS[kind](**d)


def config_to_dict(cfg: ModelConfig) -> dict:
    """JSON-safe dict; exact inverse of :func:`config_from_dict`."""
    d = {f.name: getattr(cfg, f.name)
         for f in dataclasses.fields(ModelConfig) if f.name != "pattern"}
    d["pattern"] = [{"mixer": _spec_to_dict(l.mixer),
                     "ffn": _spec_to_dict(l.ffn)} for l in cfg.pattern]
    return d


def config_from_dict(d: dict) -> ModelConfig:
    d = dict(d)
    pattern = tuple(
        LayerSpec(mixer=_spec_from_dict(l["mixer"]),
                  ffn=_spec_from_dict(l["ffn"])) for l in d.pop("pattern"))
    return ModelConfig(pattern=pattern, **d)


def scaled_down(cfg: ModelConfig, *, d_model: int = 64, head_dim: int = 16,
                d_ff: int = 128, vocab: int = 512, n_periods: int = 1,
                n_experts: Optional[int] = None, top_k: Optional[int] = None,
                d_state: int = 16, max_q: int = 4) -> ModelConfig:
    """Reduced config of the same family, for CPU smoke tests."""
    def shrink_mixer(m: MixerSpec) -> MixerSpec:
        if isinstance(m, AttentionSpec):
            n_q = min(m.n_q, max_q)
            n_kv = max(1, min(m.n_kv, n_q))
            while n_q % n_kv:
                n_kv -= 1
            return dataclasses.replace(m, n_q=n_q, n_kv=n_kv, head_dim=head_dim)
        return dataclasses.replace(
            m, d_inner=2 * d_model, d_state=d_state, head_dim=head_dim,
            chunk=8)

    def shrink_ffn(f):
        if f is None:
            return None
        if isinstance(f, MoESpec):
            ne = n_experts or min(f.n_experts, 4)
            # keep top_k < n_experts so smoke configs can exercise
            # empty-expert paths (full-size configs have top_k << E;
            # top_k == E would make every expert always occupied)
            tk = top_k or min(f.top_k, max(1, ne // 2))
            return dataclasses.replace(
                f, n_experts=ne, top_k=min(tk, ne), d_ff=d_ff)
        return dataclasses.replace(f, d_ff=d_ff)

    pattern = tuple(
        LayerSpec(mixer=shrink_mixer(l.mixer), ffn=shrink_ffn(l.ffn))
        for l in cfg.pattern)
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", d_model=d_model, vocab=vocab,
        pattern=pattern, n_periods=n_periods, vocab_pad_multiple=16,
        scan_layers=cfg.scan_layers, remat=False)
