"""Elastic scaling: derive a mesh from whatever devices are alive and
reshard checkpoints onto it.

Policy: keep the model axis as close to the target TP degree as the device
count allows (TP must divide the model's shardable dims), grow/shrink data
parallelism with the fleet. Restores go through CheckpointManager.restore
with the new mesh's shardings — no resharding-aware file format needed
because checkpoints store unsharded logical arrays.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.models.specs import ModelConfig


def choose_mesh_shape(n_devices: int, target_tp: int = 16,
                      multi_pod: bool = False) -> tuple:
    """(data, model) or (pod, data, model) sized to the live fleet."""
    tp = min(target_tp, n_devices)
    while n_devices % tp:
        tp //= 2
    dp = n_devices // tp
    if multi_pod and dp % 2 == 0 and dp > 1:
        return (2, dp // 2, tp)
    return (dp, tp)


def make_elastic_mesh(n_devices: Optional[int] = None, target_tp: int = 16,
                      multi_pod: bool = False) -> Mesh:
    devices = jax.devices()
    if n_devices is not None:
        devices = devices[:n_devices]
    shape = choose_mesh_shape(len(devices), target_tp, multi_pod)
    names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    import numpy as np
    return Mesh(np.asarray(devices).reshape(shape), names)


def reshard_state(state, mesh: Mesh, cfg: ModelConfig):
    """Move an existing (host or differently-sharded) train state onto a
    new mesh using the standard sharding policy."""
    from repro.distributed import sharding as SH
    pspecs = SH.param_shardings(mesh, cfg)

    def put(tree, shardings):
        return jax.tree.map(
            lambda x, s: jax.device_put(jax.device_get(x), s), tree, shardings)

    new_state = dict(state)
    new_state["params"] = put(state["params"], pspecs)
    if "opt" in state:
        opt = dict(state["opt"])
        opt["m"] = put(opt["m"], pspecs)
        try:
            opt["v"] = put(opt["v"], pspecs)
        except ValueError:
            pass   # factored v has different structure; leave on default
        new_state["opt"] = opt
    return new_state
