"""Fault tolerance: preemption handling, straggler detection, retries.

On a real pod these hooks fire from the cluster scheduler (SIGTERM before
preemption) and per-host step timing; here they are fully implemented and
unit-tested on one host.
"""
from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Optional



class PreemptionHandler:
    """Installs a SIGTERM/SIGINT watcher; the train loop polls
    ``should_stop`` and checkpoints before exiting."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for sig in self._signals:
            self._prev[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self._stop.set()

    def trigger(self) -> None:          # for tests / manual drains
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev.clear()


class StragglerMonitor:
    """EMA-based step-time watermark. A step slower than
    ``threshold x EMA`` is flagged; at pod scale the same watermark feeds
    the scheduler's replace-slow-host policy."""

    def __init__(self, threshold: float = 2.0, ema: float = 0.9,
                 warmup: int = 5):
        self.threshold = threshold
        self.ema_factor = ema
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.count = 0
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        self.count += 1
        is_straggler = False
        if self.ema is not None and self.count > self.warmup:
            if seconds > self.threshold * self.ema:
                self.flagged.append((step, seconds, self.ema))
                is_straggler = True
        if self.ema is None:
            self.ema = seconds
        elif not is_straggler:   # stragglers don't poison the watermark
            self.ema = self.ema_factor * self.ema + (1 - self.ema_factor) * seconds
        return is_straggler


def with_retries(fn: Callable, n_retries: int = 3, backoff: float = 0.1,
                 exceptions=(Exception,)):
    """Retry wrapper for flaky IO (data shards, checkpoint storage)."""
    def wrapped(*args, **kwargs):
        for attempt in range(n_retries + 1):
            try:
                return fn(*args, **kwargs)
            except exceptions:
                if attempt == n_retries:
                    raise
                time.sleep(backoff * (2 ** attempt))
    return wrapped
