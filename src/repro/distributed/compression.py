"""Gradient compression for cross-pod (DCN) all-reduce.

int8 quantise -> all-reduce -> dequantise, with per-leaf error feedback so
the quantisation error is re-injected next step (convergence-preserving,
1-bit-Adam style residual). Intended for the slow 'pod' axis where the
all-reduce is DCN-bound; ICI reductions stay fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error_feedback: Optional[dict] = None):
    """Quantise a gradient tree, folding in the previous step's residual.
    Returns (quantised_tree, scales_tree, new_error_feedback)."""
    if error_feedback is None:
        error_feedback = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        new_e = g32 - dequantize_int8(q, s)
        return (q, s), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    qs, es = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    q_tree = jax.tree.unflatten(treedef, [q for q, _ in qs])
    s_tree = jax.tree.unflatten(treedef, [s for _, s in qs])
    e_tree = jax.tree.unflatten(treedef, list(es))
    return q_tree, s_tree, e_tree


def compressed_psum(grads, axis_name: str,
                    error_feedback: Optional[dict] = None):
    """Inside shard_map: int8-compressed all-reduce over ``axis_name``.

    All shards agree on a common scale first (scalar pmax — cheap), then
    the int8 payload is what crosses the wire (4x less DCN traffic); the
    psum itself runs on the int32-upcast to avoid overflow across shards.
    Returns (mean_grads_fp32, new_error_feedback).
    """
    if error_feedback is None:
        error_feedback = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)
    n = jax.lax.psum(1, axis_name)

    def reduce_one(g, e):
        g32 = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    pairs = [reduce_one(g, e) for g, e in zip(flat_g, flat_e)]
    reduced = jax.tree.unflatten(treedef, [r for r, _ in pairs])
    new_e = jax.tree.unflatten(treedef, [e for _, e in pairs])
    return reduced, new_e
