"""Pipeline parallelism: GPipe schedule via shard_map + collective_permute.

Stages hold contiguous layer groups (params stacked on a leading 'stage'
axis); microbatches ripple through the ring. Used for deployments deeper
than the DP x TP mesh handles (DESIGN.md §4); correctness-tested against
the unpipelined forward on a host mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.compat import pvary, shard_map


def pipeline_forward(stage_fn: Callable, stage_params, x_microbatches,
                     mesh: Mesh, axis: str = "stage"):
    """Run ``stage_fn(params_for_stage, x) -> x`` over a pipeline.

    stage_params: pytree with leading stage axis (sharded over ``axis``).
    x_microbatches: (n_micro, mb, ...) inputs (replicated).
    Returns (n_micro, mb, ...) outputs from the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]
    total = n_micro + n_stages - 1

    def per_device(params_local, xs):
        # params_local: stage slice (leading axis 1) ; xs: all microbatches
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        # mark buffers as stage-varying from the start (VMA-stable carry)
        buf = pvary(jnp.zeros(mb_shape, xs.dtype), (axis,))
        outs = pvary(jnp.zeros((n_micro,) + mb_shape, xs.dtype),
                             (axis,))

        def step(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use the ring input
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            inp = jnp.where(stage == 0, pvary(feed, (axis,)), buf)
            out = stage_fn(params_local, inp)
            # final stage commits microbatch (t - n_stages + 1)
            commit = t - (n_stages - 1)
            do_commit = jnp.logical_and(stage == n_stages - 1, commit >= 0)
            idx = jnp.clip(commit, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, idx, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(do_commit, out, cur), idx, axis=0)
            # ring-shift activations to the next stage
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs)

        buf, outs = jax.lax.fori_loop(0, total, step, (buf, outs))
        # replicate final-stage outputs to every device
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params,
                             is_leaf=lambda x: hasattr(x, "shape")), P())
    fn = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                   out_specs=P())
    return fn(stage_params, x_microbatches)
