"""Logical-axis sharding rules (MaxText-style) + divisibility-aware hints.

Model code names tensor dimensions logically ('batch', 'heads', 'ffn', ...);
the active rule set maps them to mesh axes. ``hint`` silently drops any
mapping whose mesh-axis size does not divide the dimension — so the same
model code runs unsharded on 1 CPU device, on a 16x16 pod, and on awkward
head counts (falling back to replication instead of crashing; the roofline
report shows where the fallback costs).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "head_dim": None,
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": "data",
    "inner": "model",       # mamba d_inner / ssd heads
    "state": None,
    "fsdp": "data",         # parameter sharding axis
    # residual stream seq sharding (Megatron-style sequence parallelism);
    # enabled per-cell by the dry-run/launcher for activation memory
    "residual_seq": None,
}

_MESH: Optional[Mesh] = None
_RULES: dict = dict(DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    global _MESH, _RULES
    prev = (_MESH, _RULES)
    _MESH = mesh
    _RULES = dict(DEFAULT_RULES) if rules is None else dict(rules)
    try:
        yield
    finally:
        _MESH, _RULES = prev


def active_mesh() -> Optional[Mesh]:
    return _MESH


def _mesh_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _mesh_size(mesh, a)
        return n
    return mesh.shape.get(axis, 1)


def resolve_spec(mesh: Mesh, shape, logical, rules: Optional[dict] = None) -> P:
    """Logical names -> PartitionSpec, dropping non-dividing axes."""
    rules = rules if rules is not None else _RULES
    parts = []
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        if isinstance(axis, (tuple, list)):
            axis = tuple(a for a in axis if a in mesh.shape)
            axis = axis if axis else None
        elif axis is not None and axis not in mesh.shape:
            axis = None
        if axis is not None and dim % _mesh_size(mesh, axis) != 0:
            axis = None
        parts.append(axis)
    return P(*parts)


def hint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; no-op otherwise."""
    if _MESH is None:
        return x
    spec = resolve_spec(_MESH, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def named_sharding(mesh: Mesh, shape, logical,
                   rules: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, shape, logical, rules))


def model_axis_size() -> int:
    if _MESH is None:
        return 1
    axis = _RULES.get("heads")
    return _mesh_size(_MESH, axis) if axis else 1


def hint_heads(x: jax.Array, kv: bool = False) -> jax.Array:
    """(B, S, H, D) attention tensors: shard heads over 'model' when the
    head count divides; otherwise fall back to head_dim sharding (head_dim
    is always a multiple of 16 here). The fallback keeps awkward head
    counts (12, 40, 8, 10...) fully model-parallel via contraction-dim
    sharding instead of padding heads."""
    if _MESH is None:
        return x
    name = "kv_heads" if kv else "heads"
    spec = resolve_spec(_MESH, x.shape, ("batch", "seq", name, None))
    if spec[2] is None:
        spec = resolve_spec(_MESH, x.shape, ("batch", "seq", None, name))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
