"""Parameter / input sharding policies for the production mesh.

2-D (data x model) layout + optional pod axis:
  - weights: FSDP over 'data' on the embed dimension, tensor-parallel over
    'model' on heads / ffn / vocab / experts (ZeRO-3 + Megatron under GSPMD)
  - attention heads that do not divide the model axis fall back to
    head_dim sharding (head_dim is always a multiple of 16 here); the
    dims that divide nothing are replicated.
  - scanned stacks get a leading None (period axis never sharded).
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.specs import (AttentionSpec, LayerSpec, MambaSpec, MLPSpec,
                                ModelConfig, MoESpec)

DP_AXES = ("pod", "data")


def _size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= _size(mesh, a)
        return n
    return mesh.shape.get(axis, 1)


def _fit(mesh: Mesh, dim: int, axis):
    """axis if it exists in mesh and divides dim, else None."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        axis = tuple(a for a in axis if a in mesh.shape)
        if not axis:
            return None
        return axis if dim % _size(mesh, axis) == 0 else None
    if axis not in mesh.shape:
        return None
    return axis if dim % _size(mesh, axis) == 0 else None


def batch_axes(mesh: Mesh, dim: int):
    """Shard batch over (pod, data) with graceful fallback to data/none."""
    for cand in (DP_AXES, "data", None):
        ax = _fit(mesh, dim, cand)
        if ax is not None or cand is None:
            return ax
    return None


def attn_param_specs(mesh: Mesh, spec: AttentionSpec, d_model: int) -> dict:
    fsdp = _fit(mesh, d_model, "data")

    def qkv(n_heads):
        if _fit(mesh, n_heads, "model"):
            return P(fsdp, "model", None), P("model", None)
        if _fit(mesh, spec.head_dim, "model"):
            return P(fsdp, None, "model"), P(None, "model")
        return P(fsdp, None, None), P(None, None)

    q_spec, qb_spec = qkv(spec.n_q)
    kv_spec, kvb_spec = qkv(spec.n_kv)
    if _fit(mesh, spec.n_q, "model"):
        o_spec = P("model", None, fsdp)
    elif _fit(mesh, spec.head_dim, "model"):
        o_spec = P(None, "model", fsdp)
    else:
        o_spec = P(None, None, fsdp)
    out = {"q": q_spec, "k": kv_spec, "v": kv_spec, "o": o_spec}
    if spec.qkv_bias:
        out["q_bias"] = qb_spec
        out["k_bias"] = kvb_spec
        out["v_bias"] = kvb_spec
    return out


def mlp_param_specs(mesh: Mesh, spec: MLPSpec, d_model: int) -> dict:
    fsdp = _fit(mesh, d_model, "data")
    ff = _fit(mesh, spec.d_ff, "model")
    out = {"up": P(fsdp, ff), "down": P(ff, fsdp)}
    if spec.gated:
        out["gate"] = P(fsdp, ff)
    return out


def moe_param_specs(mesh: Mesh, spec: MoESpec, d_model: int) -> dict:
    fsdp = _fit(mesh, d_model, "data")
    ep = _fit(mesh, spec.n_experts, "model")
    out = {
        "router": P(fsdp, None),
        "up": P(ep, fsdp, None),
        "down": P(ep, None, fsdp),
    }
    if spec.gated:
        out["gate"] = P(ep, fsdp, None)
    if spec.n_shared:
        shared_ff = spec.d_ff * spec.n_shared
        out["shared"] = {
            "up": P(fsdp, _fit(mesh, shared_ff, "model")),
            "down": P(_fit(mesh, shared_ff, "model"), fsdp),
        }
        if spec.gated:
            out["shared"]["gate"] = out["shared"]["up"]
    return out


def mamba_param_specs(mesh: Mesh, spec: MambaSpec, d_model: int) -> dict:
    fsdp = _fit(mesh, d_model, "data")
    inner = _fit(mesh, spec.d_inner, "model")
    return {
        # mixed [z|x|B|C|dt] column layout sharded over model: slice
        # boundaries cross shards (XLA reshards); splitting per-component
        # is a recorded perf-iteration candidate
        "in_proj": P(fsdp, _fit(mesh, spec.in_dim, "model")),
        "conv_w": P(None, None),
        "conv_b": P(None),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm_scale": P(inner),
        "out_proj": P(inner, fsdp),
    }


def block_param_specs(mesh: Mesh, cfg: ModelConfig, spec: LayerSpec) -> dict:
    d = cfg.d_model
    out = {"norm1": {"scale": P(None)}}
    if cfg.norm == "layernorm":
        out["norm1"]["bias"] = P(None)
    if isinstance(spec.mixer, AttentionSpec):
        out["attn"] = attn_param_specs(mesh, spec.mixer, d)
    else:
        out["mamba"] = mamba_param_specs(mesh, spec.mixer, d)
    if spec.ffn is not None:
        out["norm2"] = {"scale": P(None)}
        if cfg.norm == "layernorm":
            out["norm2"]["bias"] = P(None)
        if isinstance(spec.ffn, MoESpec):
            out["moe"] = moe_param_specs(mesh, spec.ffn, d)
        else:
            out["mlp"] = mlp_param_specs(mesh, spec.ffn, d)
    return out


def _prepend_axis(tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), tree,
        is_leaf=lambda x: isinstance(x, P))


def param_specs(mesh: Mesh, cfg: ModelConfig) -> dict:
    """PartitionSpec tree matching init_model's structure."""
    vocab = _fit(mesh, cfg.padded_vocab, "model")
    fsdp = _fit(mesh, cfg.d_model, "data")
    out = {
        "embed": {"table": P(vocab, fsdp)},
        "final_norm": {"scale": P(None)},
    }
    if cfg.norm == "layernorm":
        out["final_norm"]["bias"] = P(None)
    if not cfg.tie_embeddings:
        out["lm_head"] = {"w": P(fsdp, vocab)}
    if cfg.scan_layers:
        out["blocks"] = tuple(
            _prepend_axis(block_param_specs(mesh, cfg, spec))
            for spec in cfg.pattern)
    else:
        out["blocks"] = [block_param_specs(mesh, cfg, cfg.layer(i))
                         for i in range(cfg.n_layers)]
    return out


def param_shardings(mesh: Mesh, cfg: ModelConfig):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(mesh, cfg),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- caches

def attn_cache_specs(mesh: Mesh, spec: AttentionSpec, batch: int) -> dict:
    b = batch_axes(mesh, batch)
    if _fit(mesh, spec.n_kv, "model"):
        kv = P(b, None, "model", None)
    elif _fit(mesh, spec.head_dim, "model"):
        kv = P(b, None, None, "model")
    else:
        kv = P(b, None, None, None)
    return {"k": kv, "v": kv}


def mamba_cache_specs(mesh: Mesh, spec: MambaSpec, batch: int) -> dict:
    b = batch_axes(mesh, batch)
    heads = _fit(mesh, spec.n_heads, "model")
    return {
        "conv": P(b, None, None),
        "state": P(b, heads, None, None),
    }


def cache_specs(mesh: Mesh, cfg: ModelConfig, batch: int):
    def block(spec: LayerSpec):
        if isinstance(spec.mixer, AttentionSpec):
            return {"attn": attn_cache_specs(mesh, spec.mixer, batch)}
        return {"mamba": mamba_cache_specs(mesh, spec.mixer, batch)}
    if cfg.scan_layers:
        return tuple(_prepend_axis(block(s)) for s in cfg.pattern)
    return [block(cfg.layer(i)) for i in range(cfg.n_layers)]


def cache_shardings(mesh: Mesh, cfg: ModelConfig, batch: int):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(mesh, cfg, batch),
                        is_leaf=lambda x: isinstance(x, P))


def input_sharding(mesh: Mesh, batch: int):
    return NamedSharding(mesh, P(batch_axes(mesh, batch), None))
