"""The assigned input-shape set (per-arch cells of the dry-run matrix)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    batch: int
    subquadratic_only: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           subquadratic_only=True),
}


def applicable(shape: ShapeSpec, cfg) -> bool:
    """long_500k only for sub-quadratic (SSM / hybrid) archs; decoder-only
    archs run all decode shapes."""
    if shape.subquadratic_only and not cfg.subquadratic:
        return False
    return True
