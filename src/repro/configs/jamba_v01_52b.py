"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 v=65536,
MoE 16e top-2, Mamba:attn 7:1 interleave, MoE every other layer
[arXiv:2403.19887]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MambaSpec, MLPSpec,
                                ModelConfig, MoESpec)

D = 4096


def _pattern():
    attn = AttentionSpec(n_q=32, n_kv=8, head_dim=128)
    mamba = MambaSpec(d_inner=2 * D, d_state=128, head_dim=64)
    mlp = MLPSpec(d_ff=14336, act="silu", gated=True)
    moe = MoESpec(n_experts=16, top_k=2, d_ff=14336, act="silu", gated=True)
    layers = []
    for j in range(8):                     # 1 attn per 8; MoE on odd layers
        mixer = attn if j == 4 else mamba
        ffn = moe if j % 2 == 1 else mlp
        layers.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(layers)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", d_model=D, vocab=65536,
        pattern=_pattern(), n_periods=4, norm="rmsnorm",
        scan_layers=True, remat=True, arch_class="hybrid",
        subquadratic=True, max_seq=262144)
