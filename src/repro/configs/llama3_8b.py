"""llama-3-8b — the paper's own primary model (Table II): 32L d=4096 32H
(GQA kv=8) d_ff=14336 v=128256 [arXiv:2407.21783]. Used by the quality
benchmarks (at reduced scale) and available for the dry-run."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=32, n_kv=8, head_dim=128, rope_theta=5e5)
    mlp = MLPSpec(d_ff=14336, act="silu", gated=True)
    return ModelConfig(
        name="llama3-8b", d_model=4096, vocab=128256,
        pattern=(LayerSpec(attn, mlp),), n_periods=32,
        norm="rmsnorm", scan_layers=True, remat=True,
        arch_class="dense", max_seq=8192)
