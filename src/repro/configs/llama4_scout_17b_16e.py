"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) expert d_ff=8192
v=202048, MoE 16e top-1 + 1 shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.specs import (AttentionSpec, LayerSpec, ModelConfig,
                                MoESpec)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=40, n_kv=8, head_dim=128, rope_theta=5e5)
    moe = MoESpec(n_experts=16, top_k=1, d_ff=8192, act="silu", gated=True,
                  n_shared=1)
    return ModelConfig(
        name="llama4-scout-17b-16e", d_model=5120, vocab=202048,
        pattern=(LayerSpec(attn, moe),), n_periods=48,
        norm="rmsnorm", scan_layers=True, remat=True,
        arch_class="moe", max_seq=131072)
