"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 v=151936 — M-RoPE,
dynamic resolution; vision frontend stubbed as precomputed patch embeddings
[arXiv:2409.12191]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=12, n_kv=2, head_dim=128, qkv_bias=True,
                         rope_theta=1e6)
    mlp = MLPSpec(d_ff=8960, act="silu", gated=True)
    return ModelConfig(
        name="qwen2-vl-2b", d_model=1536, vocab=151936,
        pattern=(LayerSpec(attn, mlp),), n_periods=28,
        norm="rmsnorm", scan_layers=True, remat=True,
        frontend="vision", frontend_frac=0.25,
        arch_class="vlm", max_seq=32768)
