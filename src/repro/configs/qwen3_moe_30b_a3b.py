"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) expert d_ff=768
v=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.specs import (AttentionSpec, LayerSpec, ModelConfig,
                                MoESpec)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=32, n_kv=4, head_dim=128, rope_theta=1e6)
    moe = MoESpec(n_experts=128, top_k=8, d_ff=768, act="silu", gated=True)
    return ModelConfig(
        name="qwen3-moe-30b-a3b", d_model=2048, vocab=151936,
        pattern=(LayerSpec(attn, moe),), n_periods=48,
        norm="rmsnorm", scan_layers=True, remat=True,
        arch_class="moe", max_seq=32768)
