"""qwen2-72b [dense]: 80L d=8192 64H (GQA kv=8) d_ff=29568 v=152064 — GQA,
QKV bias [arXiv:2407.10671]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=64, n_kv=8, head_dim=128, qkv_bias=True,
                         rope_theta=1e6)
    mlp = MLPSpec(d_ff=29568, act="silu", gated=True)
    return ModelConfig(
        name="qwen2-72b", d_model=8192, vocab=152064,
        pattern=(LayerSpec(attn, mlp),), n_periods=80,
        norm="rmsnorm", scan_layers=True, remat=True,
        arch_class="dense", max_seq=32768)
