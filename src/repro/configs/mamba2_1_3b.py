"""mamba2-1.3b [ssm]: 48L d=2048 attn-free, d_inner=4096 (64 SSD heads x
headdim 64), ssm_state=128, v=50280 — SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from repro.models.specs import LayerSpec, MambaSpec, ModelConfig


def config() -> ModelConfig:
    mamba = MambaSpec(d_inner=4096, d_state=128, head_dim=64)
    return ModelConfig(
        name="mamba2-1.3b", d_model=2048, vocab=50280,
        pattern=(LayerSpec(mamba, None),), n_periods=48,
        norm="rmsnorm", tie_embeddings=True,
        scan_layers=True, remat=True, arch_class="ssm",
        subquadratic=True, max_seq=1048576)
