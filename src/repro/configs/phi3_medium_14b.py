"""phi3-medium-14b [dense]: 40L d=5120 40H (GQA kv=10) d_ff=17920 v=100352 —
RoPE, SwiGLU, GQA [arXiv:2404.14219; unverified]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=40, n_kv=10, head_dim=128)
    mlp = MLPSpec(d_ff=17920, act="silu", gated=True)
    return ModelConfig(
        name="phi3-medium-14b", d_model=5120, vocab=100352,
        pattern=(LayerSpec(attn, mlp),), n_periods=40,
        norm="rmsnorm", scan_layers=True, remat=True,
        arch_class="dense", max_seq=131072)
