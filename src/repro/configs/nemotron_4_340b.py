"""nemotron-4-340b [dense]: 96L d=18432 96H (GQA kv=8) d_ff=73728 v=256000 —
squared-ReLU (no gate), LayerNorm, head_dim=192 [arXiv:2402.16819;
unverified]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=96, n_kv=8, head_dim=192)
    mlp = MLPSpec(d_ff=73728, act="relu2", gated=False)
    return ModelConfig(
        name="nemotron-4-340b", d_model=18432, vocab=256000,
        pattern=(LayerSpec(attn, mlp),), n_periods=96,
        norm="layernorm", scan_layers=True, remat=True,
        arch_class="dense", max_seq=4096)
