"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.models.specs import ModelConfig, scaled_down

ARCHS = {
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "llama4-scout-17b-16e": "repro.configs.llama4_scout_17b_16e",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    # the paper's own model family
    "llama3-8b": "repro.configs.llama3_8b",
}

ASSIGNED = [k for k in ARCHS if k != "llama3-8b"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).config()


def get_smoke_config(name: str, **kw) -> ModelConfig:
    return scaled_down(get_config(name), **kw)


def list_archs() -> list:
    return sorted(ARCHS)
