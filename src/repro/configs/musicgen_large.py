"""musicgen-large [audio]: 48L d=2048 32H (MHA kv=32) d_ff=8192 v=2048 —
decoder-only over EnCodec tokens; codec frontend stubbed as precomputed
frame embeddings [arXiv:2306.05284]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=32, n_kv=32, head_dim=64)
    mlp = MLPSpec(d_ff=8192, act="gelu", gated=False)
    return ModelConfig(
        name="musicgen-large", d_model=2048, vocab=2048,
        pattern=(LayerSpec(attn, mlp),), n_periods=48,
        norm="layernorm", scan_layers=True, remat=True,
        frontend="audio", frontend_frac=0.25,
        arch_class="audio", max_seq=8192, vocab_pad_multiple=16)
