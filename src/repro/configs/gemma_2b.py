"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) d_ff=16384 v=256000 — GeGLU,
head_dim=256, tied embeddings, sqrt(d) embed scale [arXiv:2403.08295]."""
from repro.models.specs import (AttentionSpec, LayerSpec, MLPSpec,
                                ModelConfig)


def config() -> ModelConfig:
    attn = AttentionSpec(n_q=8, n_kv=1, head_dim=256)
    mlp = MLPSpec(d_ff=16384, act="gelu", gated=True)   # GeGLU
    return ModelConfig(
        name="gemma-2b", d_model=2048, vocab=256000,
        pattern=(LayerSpec(attn, mlp),), n_periods=18,
        norm="rmsnorm", tie_embeddings=True, embed_scale=True,
        scan_layers=True, remat=True, arch_class="dense", max_seq=8192)
